"""Quantized factor subsystem: round-trip bounds, kernel parity, serving.

Covers the acceptance criteria: ``lowrank_matmul_q`` matches the bf16
reference within int8 tolerance (rel err <= 5e-2) in interpret mode, and
``ServeEngine(quantize="int8")`` produces token streams end-to-end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.layers.param import apply_linear, linear_flops, linear_out_dim
from repro.quant import (dequantize_array, dequantize_tree, is_quantized,
                         quantize_array, quantize_tree, relative_error,
                         tree_bytes)

INT8_BOUND = 0.02       # per-channel symmetric int8 on gaussian factors
FP8_BOUND = 0.06        # e4m3 has ~3 mantissa bits


# Factor leaves per kind, as the surgery produces them.
FACTOR_SHAPES = {
    "w0": (256, 64), "w1": (64, 256),
    "u": (4, 128, 32), "xc": (4, 32, 32), "v": (4, 32, 128),
    "tucker_u": (64, 16), "core": (3, 3, 16, 16), "tucker_v": (16, 64),
}


class TestRoundTrip:
    @pytest.mark.parametrize("key,shape", sorted(FACTOR_SHAPES.items()))
    @pytest.mark.parametrize("mode", ["int8", "fp8"])
    def test_error_bound_per_factor_kind(self, key, shape, mode, rng):
        w = jax.random.normal(jax.random.fold_in(rng, hash(key) % 97),
                              shape) * 0.05
        bound = INT8_BOUND if mode == "int8" else FP8_BOUND
        assert relative_error(w, mode) <= bound, (key, mode)

    def test_scale_shapes_per_output_channel(self, rng):
        w = jax.random.normal(rng, (4, 128, 32))
        q, scale = quantize_array(w)
        assert q.shape == w.shape and q.dtype == jnp.int8
        assert scale.shape == (4, 1, 32) and scale.dtype == jnp.float32

    def test_zero_channels_roundtrip_exactly(self):
        w = jnp.zeros((32, 16))
        q, scale = quantize_array(w)
        np.testing.assert_array_equal(
            np.asarray(dequantize_array(q, scale, jnp.float32)), 0.0)

    def test_tree_rewrites_factor_keys_only(self, rng):
        tree = {
            "mlp": {"up": {"w0": jax.random.normal(rng, (64, 16)),
                           "w1": jax.random.normal(rng, (16, 64))}},
            "norm": {"scale": jnp.ones((64,))},
            "dense": {"w": jax.random.normal(rng, (64, 64))},
        }
        qt = quantize_tree(tree)
        up = qt["mlp"]["up"]
        assert set(up) == {"w0_q", "w0_scale", "w1_q", "w1_scale"}
        assert is_quantized(up)
        assert "w" in qt["dense"] and "scale" in qt["norm"]  # untouched
        assert tree_bytes(qt) < tree_bytes(tree)
        # idempotent
        assert jax.tree.structure(quantize_tree(qt)) \
            == jax.tree.structure(qt)

    def test_dequantize_tree_inverts(self, rng):
        w0 = jax.random.normal(rng, (64, 16)) * 0.1
        tree = {"up": {"w0": w0, "w1": jax.random.normal(rng, (16, 64))}}
        back = dequantize_tree(quantize_tree(tree), jnp.float32)
        assert set(back["up"]) == {"w0", "w1"}
        np.testing.assert_allclose(np.asarray(back["up"]["w0"]),
                                   np.asarray(w0), atol=2e-3)


class TestKernelQ:
    SHAPES = [
        (256, 512, 128, 512),
        (300, 512, 128, 640),     # unaligned M/S -> padding path
        (8, 128, 16, 384),        # M smaller than a tile
    ]

    @pytest.mark.parametrize("m,c,r,s", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_dequant_reference(self, m, c, r, s, dtype, rng):
        ks = jax.random.split(rng, 3)
        x = (jax.random.normal(ks[0], (m, c)) * 0.1).astype(dtype)
        w0q, w0s = quantize_array(jax.random.normal(ks[1], (c, r)) * 0.05)
        w1q, w1s = quantize_array(jax.random.normal(ks[2], (r, s)) * 0.05)
        got = ops.lowrank_matmul_q(x, w0q, w0s, w1q, w1s, force_kernel=True)
        want = ref.lowrank_matmul_q_ref(x, w0q, w0s, w1q, w1s)
        assert got.dtype == want.dtype and got.shape == want.shape
        tol = dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
            else dict(atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol)

    @pytest.mark.parametrize("m,c,r,s", SHAPES)
    def test_within_int8_tolerance_of_bf16_path(self, m, c, r, s, rng):
        """Acceptance: rel err <= 5e-2 vs the unquantized bf16 kernel."""
        ks = jax.random.split(rng, 3)
        x = (jax.random.normal(ks[0], (m, c)) * 0.1).astype(jnp.bfloat16)
        w0 = jax.random.normal(ks[1], (c, r)) * 0.05
        w1 = jax.random.normal(ks[2], (r, s)) * 0.05
        w0q, w0s = quantize_array(w0)
        w1q, w1s = quantize_array(w1)
        got = ops.lowrank_matmul_q(x, w0q, w0s, w1q, w1s, force_kernel=True)
        want = ref.lowrank_matmul_ref(x, w0.astype(jnp.bfloat16),
                                      w1.astype(jnp.bfloat16))
        rel = float(jnp.linalg.norm((got - want).astype(jnp.float32))
                    / jnp.linalg.norm(want.astype(jnp.float32)))
        assert rel <= 5e-2, rel

    def test_oversize_falls_back_to_ref(self, rng):
        x = jax.random.normal(rng, (16, 16384), jnp.float32)
        w0q, w0s = quantize_array(
            jax.random.normal(rng, (16384, 4096)) * 0.01)
        w1q, w1s = quantize_array(
            jax.random.normal(rng, (4096, 8192)) * 0.01)
        got = ops.lowrank_matmul_q(x, w0q, w0s, w1q, w1s)  # no force
        want = ref.lowrank_matmul_q_ref(x, w0q, w0s, w1q, w1s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_fp8_factors_through_wrapper(self, rng):
        ks = jax.random.split(rng, 3)
        x = (jax.random.normal(ks[0], (64, 128)) * 0.1).astype(jnp.bfloat16)
        w0q, w0s = quantize_array(jax.random.normal(ks[1], (128, 32)) * 0.05,
                                  "fp8")
        w1q, w1s = quantize_array(jax.random.normal(ks[2], (32, 128)) * 0.05,
                                  "fp8")
        got = ops.lowrank_matmul_q(x, w0q, w0s, w1q, w1s, force_kernel=True)
        want = ref.lowrank_matmul_q_ref(x, w0q, w0s, w1q, w1s)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=3e-2, rtol=3e-2)


class TestKernelBranchedQ:
    """Fused quantized branched kernel vs the dequant-outside oracle.

    Acceptance: <= 1e-2 max abs err in interpret mode."""

    SHAPES = [
        (256, 512, 64, 64, 512, 4),
        (200, 256, 32, 32, 300, 2),    # unaligned M/S -> padding path
        (128, 384, 16, 32, 256, 3),    # r1 != r2, odd branch count
        (8, 128, 16, 16, 384, 2),      # M smaller than a tile
    ]

    @staticmethod
    def _factors(rng, n, c, r1, r2, s, mode="int8"):
        ks = jax.random.split(rng, 3)
        uq, us = quantize_array(
            jax.random.normal(ks[0], (n, c, r1)) * 0.05, mode)
        xcq, xcs = quantize_array(
            jax.random.normal(ks[1], (n, r1, r2)) * 0.1, mode)
        vq, vs = quantize_array(
            jax.random.normal(ks[2], (n, r2, s)) * 0.05, mode)
        return uq, us, xcq, xcs, vq, vs

    @pytest.mark.parametrize("m,c,r1,r2,s,n", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_dequant_reference(self, m, c, r1, r2, s, n, dtype, rng):
        x = (jax.random.normal(jax.random.fold_in(rng, 11), (m, c))
             * 0.1).astype(dtype)
        fs = self._factors(rng, n, c, r1, r2, s)
        got = ops.branched_matmul_q(x, *fs, force_kernel=True)
        want = ref.branched_matmul_q_ref(x, *fs)
        assert got.dtype == want.dtype and got.shape == want.shape
        err = float(jnp.abs(got.astype(jnp.float32)
                            - want.astype(jnp.float32)).max())
        assert err <= 1e-2, err

    def test_within_int8_tolerance_of_bf16_path(self, rng):
        """rel err <= 5e-2 vs the unquantized branched kernel."""
        m, c, r1, r2, s, n = 64, 256, 32, 32, 256, 4
        ks = jax.random.split(rng, 4)
        x = (jax.random.normal(ks[0], (m, c)) * 0.1).astype(jnp.bfloat16)
        u = jax.random.normal(ks[1], (n, c, r1)) * 0.05
        xc = jax.random.normal(ks[2], (n, r1, r2)) * 0.1
        v = jax.random.normal(ks[3], (n, r2, s)) * 0.05
        uq, us = quantize_array(u)
        xcq, xcs = quantize_array(xc)
        vq, vs = quantize_array(v)
        got = ops.branched_matmul_q(x, uq, us, xcq, xcs, vq, vs,
                                    force_kernel=True)
        want = ref.branched_matmul_ref(x, u.astype(jnp.bfloat16),
                                       xc.astype(jnp.bfloat16),
                                       v.astype(jnp.bfloat16))
        rel = float(jnp.linalg.norm((got - want).astype(jnp.float32))
                    / jnp.linalg.norm(want.astype(jnp.float32)))
        assert rel <= 5e-2, rel

    def test_oversize_falls_back_to_ref(self, rng):
        x = jax.random.normal(rng, (16, 16384), jnp.float32)
        fs = self._factors(rng, 1, 16384, 4096, 64, 8192)
        got = ops.branched_matmul_q(x, *fs)      # no force
        want = ref.branched_matmul_q_ref(x, *fs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_oversize_fallback_flattens_leading_dims(self, rng):
        """Regression: the ref fallback must honour the wrapper's
        leading-batch-flattening contract (3D decode-shaped x)."""
        x = jax.random.normal(rng, (2, 1, 16384), jnp.float32)
        fs = self._factors(rng, 1, 16384, 4096, 64, 8192)
        got = ops.branched_matmul_q(x, *fs)      # no force -> ref path
        assert got.shape == (2, 1, 8192)
        want = ref.branched_matmul_q_ref(x.reshape(2, 16384), *fs)
        np.testing.assert_allclose(np.asarray(got.reshape(2, 8192)),
                                   np.asarray(want), rtol=2e-2, atol=2e-2)

    def test_fp8_factors_through_wrapper(self, rng):
        x = (jax.random.normal(jax.random.fold_in(rng, 13), (64, 128))
             * 0.1).astype(jnp.bfloat16)
        fs = self._factors(rng, 2, 128, 16, 16, 128, mode="fp8")
        got = ops.branched_matmul_q(x, *fs, force_kernel=True)
        want = ref.branched_matmul_q_ref(x, *fs)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=3e-2, rtol=3e-2)


class TestApplyLinearDispatch:
    def test_lowrank_q_close_to_unquantized(self, rng):
        ks = jax.random.split(rng, 3)
        p = {"w0": jax.random.normal(ks[0], (128, 32)) * 0.1,
             "w1": jax.random.normal(ks[1], (32, 64)) * 0.1}
        x = jax.random.normal(ks[2], (2, 16, 128)) * 0.1
        y = apply_linear(p, x)
        yq = apply_linear(quantize_tree(p), x)
        assert yq.shape == y.shape
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert rel <= 5e-2, rel

    def test_lowrank_q_pallas_path(self, rng):
        ks = jax.random.split(rng, 3)
        p = quantize_tree({"w0": jax.random.normal(ks[0], (128, 32)) * 0.1,
                           "w1": jax.random.normal(ks[1], (32, 64)) * 0.1})
        x = jax.random.normal(ks[2], (16, 128)) * 0.1
        y_jnp = apply_linear(p, x)
        y_pl = apply_linear(p, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_jnp),
                                   atol=1e-4, rtol=1e-4)

    def test_branched_q_close_to_unquantized(self, rng):
        ks = jax.random.split(rng, 4)
        p = {"u": jax.random.normal(ks[0], (4, 128, 16)) * 0.1,
             "xc": jax.random.normal(ks[1], (4, 16, 16)) * 0.1,
             "v": jax.random.normal(ks[2], (4, 16, 64)) * 0.1}
        x = jax.random.normal(ks[3], (8, 128)) * 0.1
        y = apply_linear(p, x)
        yq = apply_linear(quantize_tree(p), x)
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert rel <= 5e-2, rel

    @pytest.mark.parametrize("targets", [("w0",), ("w1",)])
    def test_partial_quant_targets(self, targets, rng):
        """quant_targets may select a subset of a subtree's factors."""
        ks = jax.random.split(rng, 3)
        p = {"w0": jax.random.normal(ks[0], (128, 32)) * 0.1,
             "w1": jax.random.normal(ks[1], (32, 64)) * 0.1}
        pq = quantize_tree(p, targets=targets)
        x = jax.random.normal(ks[2], (16, 128)) * 0.1
        y = apply_linear(p, x)
        for use_pallas in (False, True):
            yq = apply_linear(pq, x, use_pallas=use_pallas)
            rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
            assert rel <= 5e-2, (targets, use_pallas, rel)

    def test_partial_branched_targets(self, rng):
        ks = jax.random.split(rng, 4)
        p = {"u": jax.random.normal(ks[0], (2, 64, 16)) * 0.1,
             "xc": jax.random.normal(ks[1], (2, 16, 16)) * 0.1,
             "v": jax.random.normal(ks[2], (2, 16, 64)) * 0.1}
        pq = quantize_tree(p, targets=("u", "v"))
        x = jax.random.normal(ks[3], (8, 64)) * 0.1
        y = apply_linear(p, x)
        yq = apply_linear(pq, x)
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert rel <= 5e-2, rel

    def test_accounting_on_quant_trees(self, rng):
        p = {"w0": jax.random.normal(rng, (128, 32)),
             "w1": jax.random.normal(rng, (32, 64))}
        pq = quantize_tree(p)
        assert linear_out_dim(pq) == linear_out_dim(p) == 64
        assert linear_flops(pq, 7) == linear_flops(p, 7)


class TestConvCoreQuant:
    """Satellite: the Tucker-conv spatial ``core`` factor rides the same
    per-channel int8 path as the matmul factors, and ``apply_conv``
    dequantizes it on the fly through the plan seam."""

    @staticmethod
    def _tucker(rng, c=16, r=8, s=16, k=3):
        ks = jax.random.split(rng, 3)
        return {"tucker_u": jax.random.normal(ks[0], (c, r)) * 0.1,
                "core": jax.random.normal(ks[1], (k, k, r, r)) * 0.1,
                "tucker_v": jax.random.normal(ks[2], (r, s)) * 0.1}

    @staticmethod
    def _branched_tucker(rng, n=2, c=16, r1=4, r2=4, s=16, k=3):
        ks = jax.random.split(rng, 3)
        return {"u": jax.random.normal(ks[0], (n, c, r1)) * 0.1,
                "core": jax.random.normal(ks[1], (n, k, k, r1, r2)) * 0.1,
                "v": jax.random.normal(ks[2], (n, r2, s)) * 0.1}

    def test_core_factor_is_quantized(self, rng):
        pq = quantize_tree(self._tucker(rng))
        assert set(pq) == {"tucker_u_q", "tucker_u_scale", "core_q",
                           "core_scale", "tucker_v_q", "tucker_v_scale"}
        assert pq["core_q"].dtype == jnp.int8
        assert pq["core_scale"].dtype == jnp.float32
        rel = relative_error(self._tucker(rng)["core"], "int8")
        assert rel <= INT8_BOUND

    def test_tucker_conv_parity(self, rng):
        from repro.layers.conv import apply_conv
        p = self._tucker(rng)
        x = jax.random.normal(jax.random.fold_in(rng, 5), (2, 8, 8, 16))
        y = apply_conv(p, x)
        yq = apply_conv(quantize_tree(p), x)
        assert yq.shape == y.shape and yq.dtype == y.dtype
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert rel <= 5e-2, rel

    def test_branched_tucker_conv_parity(self, rng):
        from repro.layers.conv import apply_conv, conv_out_channels
        p = self._branched_tucker(rng)
        pq = quantize_tree(p)
        assert pq["core_q"].dtype == jnp.int8
        assert conv_out_channels(pq) == 16
        x = jax.random.normal(jax.random.fold_in(rng, 6), (2, 8, 8, 16))
        y = apply_conv(p, x)
        yq = apply_conv(pq, x)
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert rel <= 5e-2, rel

    def test_strided_and_frozen_paths(self, rng):
        """Quantized cores survive stride-2 dispatch and the freeze
        policy (quantized factors carry no gradient anyway)."""
        from repro.layers.conv import apply_conv
        p = self._tucker(rng)
        pq = quantize_tree(p)
        x = jax.random.normal(jax.random.fold_in(rng, 7), (1, 8, 8, 16))
        y = apply_conv(p, x, stride=2)
        yq = apply_conv(pq, x, stride=2, freeze_factors=True)
        assert yq.shape == y.shape
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert rel <= 5e-2, rel


@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import registry
    from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
    from repro.core.surgery import decompose_model
    from repro.models.api import get_model

    cfg = registry.get("llama3.2-1b").smoke
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=32)
    run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    p2, _, _ = decompose_model(params, axes, lrd)
    return run, p2


class TestServeQuantized:
    def test_int8_engine_end_to_end(self, serve_setup):
        from repro.serve.engine import Request, ServeEngine
        run, params = serve_setup
        eng = ServeEngine(run, params, slots=2, max_seq=64,
                          quantize="int8")
        assert tree_bytes(eng.params) < tree_bytes(params)
        reqs = [Request(uid=i, prompt=[i + 1, 2, 3], max_new_tokens=4)
                for i in range(3)]
        for r in reqs:
            eng.add_request(r)
        done = eng.run_until_done()
        assert {r.uid for r in done} == {0, 1, 2}
        assert all(r.done and len(r.output) == 4 for r in reqs)

    def test_config_knob_quantizes_at_load(self, serve_setup):
        from repro.serve.engine import Request, ServeEngine
        run, params = serve_setup
        run_q = run.replace(lrd=dataclasses.replace(run.lrd,
                                                    quantize="int8"))
        eng = ServeEngine(run_q, params, slots=1, max_seq=64)
        assert eng.quantize == "int8"
        assert tree_bytes(eng.params) < tree_bytes(params)
        req = Request(uid=0, prompt=[5, 9, 2], max_new_tokens=3)
        eng.add_request(req)
        assert [r.uid for r in eng.run_until_done()] == [0]

    def test_run_until_done_returns_finished(self, serve_setup):
        """Satellite regression: run_until_done used to return []."""
        from repro.serve.engine import Request, ServeEngine
        run, params = serve_setup
        eng = ServeEngine(run, params, slots=2, max_seq=64)
        first = [Request(uid=i, prompt=[i + 1, 4], max_new_tokens=3)
                 for i in range(3)]
        for r in first:
            eng.add_request(r)
        done = eng.run_until_done()
        assert done == first[:len(done)] or \
            {r.uid for r in done} == {0, 1, 2}
        assert all(r.done for r in done) and len(done) == 3
        # a second call reports only newly finished requests
        late = Request(uid=9, prompt=[7], max_new_tokens=2)
        eng.add_request(late)
        assert eng.run_until_done() == [late]

"""Serving engine: continuous batching, slot reuse, greedy correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
from repro.models.api import get_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("llama3.2-1b").smoke
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _greedy_reference(m, params, prompt, n, max_seq):
    """Reference greedy decode via repeated full forward."""
    toks = list(prompt)
    for _ in range(n):
        x, _ = m.forward(params, {"tokens": jnp.asarray([toks])})
        logits = m.logits(params, x)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


class TestServeEngine:
    def test_outputs_match_reference_exactly(self, setup):
        run, m, params = setup
        eng = ServeEngine(run, params, slots=2, max_seq=64)
        prompt = [5, 9, 2, 7]
        req = Request(uid=0, prompt=prompt, max_new_tokens=6)
        eng.add_request(req)
        eng.run_until_done()
        assert req.done and len(req.output) == 6
        ref = _greedy_reference(m, params, prompt, 6, 64)
        assert req.output == ref

    def test_continuous_batching_slot_reuse(self, setup):
        run, m, params = setup
        eng = ServeEngine(run, params, slots=2, max_seq=64)
        reqs = [Request(uid=i, prompt=[i + 1, i + 2, i + 3],
                        max_new_tokens=3 + i % 3) for i in range(5)]
        for r in reqs:
            eng.add_request(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        for r in reqs:
            assert len(r.output) == r.max_new_tokens
        # batching actually happened (2 slots, 5 requests)
        assert max(s["live"] for s in eng.stats) == 2
        assert eng.throughput()["tokens_per_s"] > 0

    def test_batched_outputs_equal_isolated(self, setup):
        """Slot interference check: results identical whether a request
        runs alone or batched with others."""
        run, m, params = setup
        prompts = [[3, 1, 4], [1, 5, 9, 2], [6, 5]]
        solo = []
        for i, p in enumerate(prompts):
            eng = ServeEngine(run, params, slots=1, max_seq=64)
            r = Request(uid=i, prompt=p, max_new_tokens=5)
            eng.add_request(r)
            eng.run_until_done()
            solo.append(r.output)
        eng = ServeEngine(run, params, slots=3, max_seq=64)
        batched = [Request(uid=i, prompt=p, max_new_tokens=5)
                   for i, p in enumerate(prompts)]
        for r in batched:
            eng.add_request(r)
        eng.run_until_done()
        for s, b in zip(solo, batched):
            assert s == b.output

    def test_decomposed_model_serves(self, setup):
        """LRD-compressed params serve through the same engine."""
        run, m, params = setup
        from repro.core.surgery import decompose_model
        _, axes = m.init(jax.random.PRNGKey(0))
        lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=32)
        p2, _, _ = decompose_model(params, axes, lrd)
        run2 = dataclasses.replace(run, lrd=lrd)
        eng = ServeEngine(run2, p2, slots=2, max_seq=64)
        req = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4)
        eng.add_request(req)
        eng.run_until_done()
        assert req.done and len(req.output) == 4

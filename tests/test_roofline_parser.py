"""Roofline HLO parser: exact FLOPs / collective bytes / trip scaling,
validated against hand-computed workloads compiled on the host."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.roofline import analyze_hlo, parse_hlo, roofline


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


class TestParser:
    def test_single_dot_flops(self):
        txt = _compile(lambda a, b: a @ b,
                       jax.ShapeDtypeStruct((128, 256), jnp.float32),
                       jax.ShapeDtypeStruct((256, 512), jnp.float32))
        costs = analyze_hlo(txt, 1)
        assert costs.flops == pytest.approx(2 * 128 * 256 * 512, rel=0.01)

    def test_scan_trip_scaling(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y
        txt = _compile(f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
                       jax.ShapeDtypeStruct((64, 64), jnp.float32))
        costs = analyze_hlo(txt, 1)
        assert costs.flops == pytest.approx(7 * 2 * 32 * 64 * 64, rel=0.02)
        assert 7 in costs.while_trips.values()

    def test_nested_scan(self):
        def f(x, w):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y
        txt = _compile(f, jax.ShapeDtypeStruct((16, 32), jnp.float32),
                       jax.ShapeDtypeStruct((32, 32), jnp.float32))
        costs = analyze_hlo(txt, 1)
        assert costs.flops == pytest.approx(15 * 2 * 16 * 32 * 32, rel=0.02)

    def test_conv_flops(self):
        def f(x, w):
            return jax.lax.conv_general_dilated(
                x, w, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        txt = _compile(f, jax.ShapeDtypeStruct((2, 8, 8, 16), jnp.float32),
                       jax.ShapeDtypeStruct((3, 3, 16, 32), jnp.float32))
        costs = analyze_hlo(txt, 1)
        want = 2 * (2 * 8 * 8 * 32) * (3 * 3 * 16)
        assert costs.flops == pytest.approx(want, rel=0.05)

    def test_memory_traffic_positive_and_sane(self):
        txt = _compile(lambda a, b: a @ b,
                       jax.ShapeDtypeStruct((128, 256), jnp.float32),
                       jax.ShapeDtypeStruct((256, 512), jnp.float32))
        costs = analyze_hlo(txt, 1)
        io_bytes = 4 * (128 * 256 + 256 * 512 + 128 * 512)
        assert io_bytes * 0.5 <= costs.hbm_bytes <= io_bytes * 4


class TestRoofline:
    def test_bottleneck_selection(self):
        from repro.analysis.roofline import HloCosts
        c = HloCosts(flops=1e12, hbm_bytes=1e6, collective_bytes=0)
        r = roofline(c, n_devices=1, model_flops_global=5e11)
        assert r.bottleneck == "compute"
        assert r.useful_ratio == pytest.approx(0.5)
        c2 = HloCosts(flops=1e9, hbm_bytes=1e12, collective_bytes=0)
        assert roofline(c2, n_devices=1,
                        model_flops_global=1e9).bottleneck == "memory"

    def test_terms_use_hw_constants(self):
        from repro.analysis.hw_specs import TPU_V5E
        from repro.analysis.roofline import HloCosts
        c = HloCosts(flops=TPU_V5E.peak_flops_bf16,
                     hbm_bytes=TPU_V5E.hbm_bandwidth,
                     collective_bytes=TPU_V5E.ici_link_bandwidth)
        r = roofline(c, n_devices=1, model_flops_global=1.0)
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(1.0)
        assert r.collective_s == pytest.approx(1.0)

"""Training stack: optimizer, compression, checkpoints, fault tolerance,
the full loop (resume / preemption / straggler / fault-injection)."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import LRDConfig, ParallelConfig, RunConfig, ShapeConfig
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import optim
from repro.train.data import ByteTextLM, DataState, SyntheticLM
from repro.train.fault_tolerance import (PreemptionHandler, StragglerDetector,
                                         run_with_restart)
from repro.train.loop import train

SHAPE = ShapeConfig("smoke", 64, 2, "train")


def tiny_run(**kw):
    cfg = registry.get("llama3.2-1b").smoke
    par = ParallelConfig(remat="none")
    lrd = kw.pop("lrd", LRDConfig())
    return RunConfig(model=cfg, parallel=par, lrd=lrd)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class TestOptim:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        cfg = optim.OptimConfig(peak_lr=0.1, warmup_steps=1, total_steps=50,
                                weight_decay=0.0, grad_clip=0)
        state = optim.adamw_init(params)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            params, state, _ = optim.adamw_update(grads, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.6

    def test_masked_leaves_not_updated_and_stateless(self):
        params = {"a": jnp.ones(4), "b": jnp.ones(4)}
        mask = {"a": True, "b": False}
        state = optim.adamw_init(params, mask)
        assert state["m"]["b"].size == 0       # no moment memory
        grads = {"a": jnp.ones(4), "b": jnp.ones(4)}
        cfg = optim.OptimConfig(peak_lr=0.1, warmup_steps=1, total_steps=10)
        p2, _, _ = optim.adamw_update(grads, state, params, cfg, mask)
        assert float(jnp.abs(p2["b"] - 1.0).max()) == 0
        assert float(jnp.abs(p2["a"] - 1.0).max()) > 0

    def test_lr_schedule(self):
        cfg = optim.OptimConfig(peak_lr=1.0, warmup_steps=10,
                                total_steps=100, min_lr_frac=0.1)
        assert float(optim.lr_schedule(cfg, jnp.asarray(5))) == \
            pytest.approx(0.5, rel=0.1)
        assert float(optim.lr_schedule(cfg, jnp.asarray(100))) == \
            pytest.approx(0.1, rel=0.05)


# ---------------------------------------------------------------------------
# PowerSGD compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_lowrank_grad_exact(self, rng):
        """A gradient that IS rank-r is transmitted losslessly."""
        g = {"w": jax.random.normal(rng, (64, 4)) @
                  jax.random.normal(jax.random.fold_in(rng, 1), (4, 48))}
        cfg = comp.CompressionConfig(rank=4, min_dim=4)
        st = comp.init_state(g, cfg, rng)
        out, st2, stats = comp.compress_decompress(g, st, cfg, lambda x: x)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(g["w"]), atol=1e-3)
        assert stats["bytes_sent"] < stats["bytes_raw"]

    def test_error_feedback_accumulates(self, rng):
        """EF: compression residual is re-injected; over repeated identical
        gradients the *average* transmitted gradient converges to g."""
        g = {"w": jax.random.normal(rng, (32, 32))}
        cfg = comp.CompressionConfig(rank=2, min_dim=4)
        st = comp.init_state(g, cfg, rng)
        total = jnp.zeros_like(g["w"])
        n = 30
        for _ in range(n):
            out, st, _ = comp.compress_decompress(g, st, cfg, lambda x: x)
            total = total + out["w"]
        avg = total / n
        rel = float(jnp.linalg.norm(avg - g["w"])
                    / jnp.linalg.norm(g["w"]))
        # one-shot rank-2 of a random 32x32 has rel err ~0.95; EF drives
        # the *average* transmitted gradient far below that
        assert rel < 0.45

    def test_small_tensors_uncompressed(self, rng):
        g = {"bias": jnp.ones(8)}
        cfg = comp.CompressionConfig(rank=4, min_dim=64)
        st = comp.init_state(g, cfg, rng)
        out, _, stats = comp.compress_decompress(g, st, cfg, lambda x: x)
        np.testing.assert_allclose(np.asarray(out["bias"]), 1.0)
        assert stats["bytes_sent"] == stats["bytes_raw"]


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def _tree(self, rng):
        return {"params": {"w": jax.random.normal(rng, (8, 8))},
                "opt": {"step": jnp.asarray(7)}}

    def test_roundtrip(self, tmp_path, rng):
        tree = self._tree(rng)
        ckpt.save(str(tmp_path), 7, tree, meta={"loss": 1.5})
        got, manifest = ckpt.restore_latest(str(tmp_path), tree)
        np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                                   np.asarray(tree["params"]["w"]))
        assert manifest["step"] == 7 and manifest["meta"]["loss"] == 1.5

    def test_corruption_detected_and_skipped(self, tmp_path, rng):
        tree = self._tree(rng)
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, tree)
        # corrupt the newest
        with open(os.path.join(str(tmp_path), "step_00000002",
                               "arrays.npz"), "r+b") as f:
            f.seek(10)
            f.write(b"\xde\xad\xbe\xef")
        name = ckpt.latest_valid(str(tmp_path))
        assert name == "step_00000001"

    def test_atomic_no_partial(self, tmp_path, rng):
        """A .tmp dir left behind never counts as a checkpoint."""
        tree = self._tree(rng)
        os.makedirs(os.path.join(str(tmp_path), ".tmp-step_00000009"))
        ckpt.save(str(tmp_path), 3, tree)
        assert ckpt.latest_valid(str(tmp_path)) == "step_00000003"

    def test_async_writer(self, tmp_path, rng):
        tree = self._tree(rng)
        w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3):
            w.save(s, tree)
        w.close()
        names = ckpt.list_steps(str(tmp_path))
        assert names[-1] == "step_00000003"
        assert len(names) <= 2               # gc kept 2


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_synthetic_deterministic_resume(self):
        cfg = registry.get("llama3.2-1b").smoke
        ds = SyntheticLM(cfg, SHAPE, seed=3)
        s0 = DataState()
        stream = ds.stream(s0)
        batches = [next(stream) for _ in range(5)]
        # resume from step 3 reproduces batch 3 exactly
        b3, _ = next(ds.stream(batches[2][1]))
        np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                      np.asarray(batches[3][0]["tokens"]))

    def test_byte_text_shapes_and_resume(self):
        cfg = registry.get("llama3.2-1b").smoke
        ds = ByteTextLM(cfg, batch=2, seq_len=32)
        b0 = ds.batch(0)
        assert b0["tokens"].shape == (2, 32)
        np.testing.assert_array_equal(np.asarray(ds.batch(5)["tokens"]),
                                      np.asarray(ds.batch(5)["tokens"]))


# ---------------------------------------------------------------------------
# fault tolerance + loop integration
# ---------------------------------------------------------------------------

class TestFaultTolerance:
    def test_straggler_detector(self):
        import time
        d = StragglerDetector(threshold=3.0, warmup=1)
        for i in range(6):
            d.start()
            time.sleep(0.002 if i != 4 else 0.05)
            d.stop(i)
        assert [e.step for e in d.events] == [4]

    def test_run_with_restart(self):
        calls = []

        def fn(attempt):
            calls.append(attempt)
            if attempt < 2:
                raise RuntimeError("injected node failure")
            return {"ok": True}

        out = run_with_restart(fn, max_restarts=3)
        assert out["restarts"] == 2 and calls == [0, 1, 2]


@pytest.mark.slow
class TestLoopIntegration:
    def test_train_checkpoint_resume_identical(self, tmp_path):
        """Train 6 steps straight vs 3 + resume + 3: identical loss path
        (deterministic data stream + exact state restore)."""
        run = tiny_run()
        cfg = run.model
        data = SyntheticLM(cfg, SHAPE, seed=1)
        r_full = train(run, data, num_steps=6, ckpt_dir=None, log_every=0,
                       log_fn=lambda s: None)
        d1 = str(tmp_path / "ck")
        r_a = train(run, data, num_steps=3, ckpt_dir=d1, ckpt_every=1,
                    log_every=0, log_fn=lambda s: None)
        r_b = train(run, data, num_steps=6, ckpt_dir=d1, ckpt_every=3,
                    resume=True, log_every=0, log_fn=lambda s: None)
        assert r_b.resumed_from == 3
        np.testing.assert_allclose(r_full.losses[3:], r_b.losses,
                                   rtol=2e-4, atol=2e-4)

    def test_fault_injection_restart(self, tmp_path):
        """A crash at step 4 restarts from the last checkpoint and
        completes — no step skipped or repeated in the loss path."""
        run = tiny_run()
        data = SyntheticLM(run.model, SHAPE, seed=1)
        d = str(tmp_path / "ck")
        crashed = {"done": False}

        def fault_hook(step):
            if step == 4 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected crash")

        def attempt(i):
            r = train(run, data, num_steps=6, ckpt_dir=d, ckpt_every=1,
                      fault_hook=fault_hook, log_every=0,
                      log_fn=lambda s: None)
            return {"result": r}

        out = run_with_restart(attempt, max_restarts=2)
        assert out["restarts"] == 1
        assert out["result"].step == 6

    def test_preemption_checkpoints_and_exits(self, tmp_path):
        run = tiny_run()
        data = SyntheticLM(run.model, SHAPE, seed=1)
        d = str(tmp_path / "ck")
        handler = PreemptionHandler(signals=())

        def hook(step):
            if step == 2:
                handler.request()

        r = train(run, data, num_steps=10, ckpt_dir=d, ckpt_every=100,
                  fault_hook=hook, preemption=handler, log_every=0,
                  log_fn=lambda s: None)
        assert r.step == 3                    # stopped early
        assert ckpt.latest_valid(d) is not None

    def test_freezing_trains_only_live_factors(self, tmp_path):
        """Paper §2.2 end-to-end: frozen factors identical after training."""
        run = tiny_run(lrd=LRDConfig(enabled=True, rank_mode="ratio",
                                     min_dim=32, freeze=True))
        data = SyntheticLM(run.model, SHAPE, seed=1)
        from repro.core.surgery import decompose_model
        from repro.models.api import get_model
        from repro.train.steps import init_opt_state, make_train_step
        from repro.train.optim import OptimConfig

        m = get_model(run.model)
        params, axes = m.init(jax.random.PRNGKey(0))
        params, _, _ = decompose_model(params, axes, run.lrd)
        w0_before = np.asarray(params["blocks"]["mlp"]["up"]["w0"])
        ocfg = OptimConfig(peak_lr=1e-2, warmup_steps=1, total_steps=3)
        opt = init_opt_state(m, run, params, ocfg)
        step = jax.jit(make_train_step(m, run, ocfg))
        batch = data.batch(0)
        for _ in range(3):
            params, opt, _ = step(params, opt, batch)
        w0_after = np.asarray(params["blocks"]["mlp"]["up"]["w0"])
        np.testing.assert_array_equal(w0_before, w0_after)
        # the live factor moved
        m_state = opt["adam"]["m"]["blocks"]["mlp"]["up"]
        assert m_state["w0"].size == 0        # frozen: no moments
        assert float(jnp.abs(m_state["w1"]).max()) > 0

"""Sharding-rule unit tests on a tiny host mesh (no forced device count)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.layers.param import (EMBED, EXPERTS, FFN, LAYERS, QKV, RANK,
                                VOCAB)
from repro.parallel import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # single CPU device: (1, 1) mesh — rule resolution is shape-logic only
    return jax.make_mesh((1, 1), ("data", "model"))


def spec_of(mesh, axes, shape, parallel):
    tree_p = {"x": jax.ShapeDtypeStruct(shape, jnp.float32)}
    tree_a = {"x": axes}
    s = shd.make_param_shardings(mesh, tree_p, tree_a, parallel)
    return s["x"].spec


class TestParamRules:
    def test_megatron_pattern(self, mesh):
        par = ParallelConfig()
        assert spec_of(mesh, (EMBED, FFN), (64, 128), par) == P(None, "model")
        assert spec_of(mesh, (FFN, EMBED), (128, 64), par) == P("model")

    def test_fsdp_2d(self, mesh):
        par = ParallelConfig(fsdp=True)
        assert spec_of(mesh, (EMBED, FFN), (64, 128), par) \
            == P("data", "model")

    def test_rank_inherits_fsdp(self, mesh):
        par = ParallelConfig(fsdp=True)
        # w1 of an expert bank: (EXPERTS, RANK, FFN)
        got = spec_of(mesh, (EXPERTS, RANK, FFN), (4, 8, 128), par)
        assert got == P("model", "data")  # EP + rank-FSDP; FFN loses model

    def test_rank_replicated_by_default(self, mesh):
        par = ParallelConfig()
        assert spec_of(mesh, (EMBED, RANK), (64, 8), par) == P()

    def test_shard_rank_variant(self, mesh):
        par = ParallelConfig(shard_rank=True)
        assert spec_of(mesh, (EMBED, RANK), (64, 8), par) == P(None, "model")
        # conflict: output dim wins the model axis over rank
        assert spec_of(mesh, (RANK, FFN), (8, 128), par) == P(None, "model")

    def test_indivisible_replicates_with_note(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        par = ParallelConfig()
        notes = []
        tree_p = {"x": jax.ShapeDtypeStruct((7, 13), jnp.float32)}
        tree_a = {"x": (VOCAB, EMBED)}
        # fake a mesh dim >1 via a purpose-built check: use model size 1 ->
        # always divisible; so instead check the note machinery directly
        from repro.parallel.sharding import _spec_for

        class FakeMesh:
            shape = {"data": 16, "model": 16}
        got = _spec_for((VOCAB, EMBED), (50280, 64), {VOCAB: "model",
                                                      EMBED: None},
                        FakeMesh(), notes, "embed/w")
        assert got == P()
        assert notes and "not divisible" in notes[0]

    def test_layer_stack_axis_never_sharded(self, mesh):
        par = ParallelConfig(fsdp=True)
        got = spec_of(mesh, (LAYERS, EMBED, QKV), (4, 64, 128), par)
        assert got == P(None, "data", "model")


class TestQuantizedParamRules:
    """Quant-aware sharding: trees rewritten by quantize_tree *after* the
    axes were built still resolve — ``k_q`` inherits ``k``'s spec,
    ``k_scale`` shards on the out dim (or replicates)."""

    def _shardings(self, mesh, params, axes, par, **quant_kw):
        from repro.quant import quantize_tree
        qp = quantize_tree(params, **quant_kw)
        return qp, shd.make_param_shardings(mesh, qp, axes, par)

    def test_svd_pair_q_inherits_base_spec(self, mesh):
        par = ParallelConfig(fsdp=True)
        params = {"up": {"w0": jnp.ones((64, 8)), "w1": jnp.ones((8, 128))}}
        axes = {"up": {"w0": (EMBED, RANK), "w1": (RANK, FFN)}}
        qp, s = self._shardings(mesh, params, axes, par)
        assert set(qp["up"]) == {"w0_q", "w0_scale", "w1_q", "w1_scale"}
        base = shd.make_param_shardings(mesh, params, axes, par)
        assert s["up"]["w0_q"].spec == base["up"]["w0"].spec
        assert s["up"]["w1_q"].spec == base["up"]["w1"].spec
        # scales: input axis collapsed to 1 -> out dim shards, rest None
        assert s["up"]["w1_scale"].spec == P(None, "model")
        assert s["up"]["w0_scale"].spec == P(None, "data")  # rank FSDP-shards

    def test_branched_q_inherits_base_spec(self, mesh):
        from repro.layers.param import BRANCH
        par = ParallelConfig(fsdp=True, shard_rank=True)
        params = {"u": jnp.ones((4, 64, 8)), "xc": jnp.ones((4, 8, 8)),
                  "v": jnp.ones((4, 8, 128))}
        axes = {"u": (BRANCH, EMBED, RANK), "xc": (BRANCH, RANK, RANK),
                "v": (BRANCH, RANK, FFN)}
        qp, s = self._shardings(mesh, params, axes, par)
        base = shd.make_param_shardings(mesh, params, axes, par)
        for k in ("u", "xc", "v"):
            assert s[k + "_q"].spec == base[k].spec, k
        assert s["v_scale"].spec == P(None, None, "model")

    def test_partial_quant_targets_mixed_tree(self, mesh):
        par = ParallelConfig(fsdp=True)
        params = {"w0": jnp.ones((64, 8)), "w1": jnp.ones((8, 128))}
        axes = {"w0": (EMBED, RANK), "w1": (RANK, FFN)}
        qp, s = self._shardings(mesh, params, axes, par, targets=("w0",))
        assert set(qp) == {"w0_q", "w0_scale", "w1"}
        base = shd.make_param_shardings(mesh, params, axes, par)
        assert s["w0_q"].spec == base["w0"].spec
        assert s["w1"].spec == base["w1"].spec

    def test_quantize_tree_rewrites_axes_tree(self):
        from repro.layers.param import NONE
        from repro.quant import quantize_tree, scale_axes
        params = {"up": {"w0": jnp.ones((64, 8)), "w1": jnp.ones((8, 128))},
                  "norm": {"scale": jnp.ones((64,))}}
        axes = {"up": {"w0": (EMBED, RANK), "w1": (RANK, FFN)},
                "norm": {"scale": (EMBED,)}}
        qp, qa = quantize_tree(params, axes=axes)
        assert qa["up"]["w0_q"] == (EMBED, RANK)
        assert qa["up"]["w0_scale"] == (NONE, RANK)
        assert qa["up"]["w1_scale"] == scale_axes((RANK, FFN)) == (NONE, FFN)
        assert qa["norm"]["scale"] == (EMBED,)          # untouched
        # rewritten axes resolve without the alignment fallback too
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        s = shd.make_param_shardings(mesh, qp, qa, ParallelConfig(fsdp=True))
        assert s["up"]["w1_scale"].spec == P(None, "model")

    def test_unresolvable_key_raises(self, mesh):
        from repro.quant import align_quantized_axes
        with pytest.raises(KeyError):
            align_quantized_axes({"mystery": jnp.ones((2, 2))},
                                 {"w0": (EMBED, RANK)})

    def test_quantize_tree_missing_axes_entry_raises(self):
        from repro.quant import quantize_tree
        params = {"w0": jnp.ones((64, 8)), "w1": jnp.ones((8, 128))}
        with pytest.raises(KeyError, match="w1"):
            quantize_tree(params, axes={"w0": (EMBED, RANK)})


class TestCacheRules:
    def test_kv_cache_seq_over_model(self, mesh):
        par = ParallelConfig()
        spec = {"k": jax.ShapeDtypeStruct((4, 8, 128, 2, 16), jnp.bfloat16)}
        got = shd.cache_shardings(mesh, spec, par, batch=8, seq_len=128)
        assert got["k"].spec == P(None, "data", "model")

    def test_b1_decode_seq_both_axes(self):
        # abstract 16x16 mesh: B=1 is NOT divisible by data -> the seq dim
        # takes both axes (the long_500k decode layout)
        try:
            mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
        except TypeError:   # older jax: one tuple of (name, size) pairs
            mesh = jax.sharding.AbstractMesh(
                (("data", 16), ("model", 16)))
        par = ParallelConfig(decode_seq_shard=True)
        spec = {"k": jax.ShapeDtypeStruct((2, 1, 512, 2, 16), jnp.bfloat16)}
        got = shd.cache_shardings(mesh, spec, par, batch=1, seq_len=512)
        assert got["k"].spec == P(None, None, ("data", "model"))

    def test_ssm_state_heads_over_model(self, mesh):
        par = ParallelConfig()
        spec = {"ssm": jax.ShapeDtypeStruct((4, 8, 16, 8, 4), jnp.float32)}
        got = shd.cache_shardings(mesh, spec, par, batch=8, seq_len=999)
        assert got["ssm"].spec == P(None, "data", "model")


class TestActivationRules:
    def test_batch_and_ffn(self, mesh):
        par = ParallelConfig()
        rule = shd.activation_resolver(mesh, par)
        from repro.layers.param import BATCH, SEQ
        s = rule((BATCH, SEQ, FFN), (8, 16, 64))
        assert s.spec == P("data", None, "model")

    def test_seq_shard_toggle(self, mesh):
        from repro.layers.param import BATCH, SEQ
        on = shd.activation_resolver(mesh, ParallelConfig(seq_shard=True))
        off = shd.activation_resolver(mesh, ParallelConfig())
        assert on((BATCH, SEQ, EMBED), (8, 16, 64)).spec \
            == P("data", "model")
        assert off((BATCH, SEQ, EMBED), (8, 16, 64)).spec == P("data")

"""Sharding-rule unit tests on a tiny host mesh (no forced device count)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.layers.param import (EMBED, EXPERTS, FFN, LAYERS, QKV, RANK,
                                VOCAB)
from repro.parallel import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # single CPU device: (1, 1) mesh — rule resolution is shape-logic only
    return jax.make_mesh((1, 1), ("data", "model"))


def spec_of(mesh, axes, shape, parallel):
    tree_p = {"x": jax.ShapeDtypeStruct(shape, jnp.float32)}
    tree_a = {"x": axes}
    s = shd.make_param_shardings(mesh, tree_p, tree_a, parallel)
    return s["x"].spec


class TestParamRules:
    def test_megatron_pattern(self, mesh):
        par = ParallelConfig()
        assert spec_of(mesh, (EMBED, FFN), (64, 128), par) == P(None, "model")
        assert spec_of(mesh, (FFN, EMBED), (128, 64), par) == P("model")

    def test_fsdp_2d(self, mesh):
        par = ParallelConfig(fsdp=True)
        assert spec_of(mesh, (EMBED, FFN), (64, 128), par) \
            == P("data", "model")

    def test_rank_inherits_fsdp(self, mesh):
        par = ParallelConfig(fsdp=True)
        # w1 of an expert bank: (EXPERTS, RANK, FFN)
        got = spec_of(mesh, (EXPERTS, RANK, FFN), (4, 8, 128), par)
        assert got == P("model", "data")  # EP + rank-FSDP; FFN loses model

    def test_rank_replicated_by_default(self, mesh):
        par = ParallelConfig()
        assert spec_of(mesh, (EMBED, RANK), (64, 8), par) == P()

    def test_shard_rank_variant(self, mesh):
        par = ParallelConfig(shard_rank=True)
        assert spec_of(mesh, (EMBED, RANK), (64, 8), par) == P(None, "model")
        # conflict: output dim wins the model axis over rank
        assert spec_of(mesh, (RANK, FFN), (8, 128), par) == P(None, "model")

    def test_indivisible_replicates_with_note(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        par = ParallelConfig()
        notes = []
        tree_p = {"x": jax.ShapeDtypeStruct((7, 13), jnp.float32)}
        tree_a = {"x": (VOCAB, EMBED)}
        # fake a mesh dim >1 via a purpose-built check: use model size 1 ->
        # always divisible; so instead check the note machinery directly
        from repro.parallel.sharding import _spec_for

        class FakeMesh:
            shape = {"data": 16, "model": 16}
        got = _spec_for((VOCAB, EMBED), (50280, 64), {VOCAB: "model",
                                                      EMBED: None},
                        FakeMesh(), notes, "embed/w")
        assert got == P()
        assert notes and "not divisible" in notes[0]

    def test_layer_stack_axis_never_sharded(self, mesh):
        par = ParallelConfig(fsdp=True)
        got = spec_of(mesh, (LAYERS, EMBED, QKV), (4, 64, 128), par)
        assert got == P(None, "data", "model")


class TestCacheRules:
    def test_kv_cache_seq_over_model(self, mesh):
        par = ParallelConfig()
        spec = {"k": jax.ShapeDtypeStruct((4, 8, 128, 2, 16), jnp.bfloat16)}
        got = shd.cache_shardings(mesh, spec, par, batch=8, seq_len=128)
        assert got["k"].spec == P(None, "data", "model")

    def test_b1_decode_seq_both_axes(self):
        # abstract 16x16 mesh: B=1 is NOT divisible by data -> the seq dim
        # takes both axes (the long_500k decode layout)
        try:
            mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
        except TypeError:   # older jax: one tuple of (name, size) pairs
            mesh = jax.sharding.AbstractMesh(
                (("data", 16), ("model", 16)))
        par = ParallelConfig(decode_seq_shard=True)
        spec = {"k": jax.ShapeDtypeStruct((2, 1, 512, 2, 16), jnp.bfloat16)}
        got = shd.cache_shardings(mesh, spec, par, batch=1, seq_len=512)
        assert got["k"].spec == P(None, None, ("data", "model"))

    def test_ssm_state_heads_over_model(self, mesh):
        par = ParallelConfig()
        spec = {"ssm": jax.ShapeDtypeStruct((4, 8, 16, 8, 4), jnp.float32)}
        got = shd.cache_shardings(mesh, spec, par, batch=8, seq_len=999)
        assert got["ssm"].spec == P(None, "data", "model")


class TestActivationRules:
    def test_batch_and_ffn(self, mesh):
        par = ParallelConfig()
        rule = shd.activation_resolver(mesh, par)
        from repro.layers.param import BATCH, SEQ
        s = rule((BATCH, SEQ, FFN), (8, 16, 64))
        assert s.spec == P("data", None, "model")

    def test_seq_shard_toggle(self, mesh):
        from repro.layers.param import BATCH, SEQ
        on = shd.activation_resolver(mesh, ParallelConfig(seq_shard=True))
        off = shd.activation_resolver(mesh, ParallelConfig())
        assert on((BATCH, SEQ, EMBED), (8, 16, 64)).spec \
            == P("data", "model")
        assert off((BATCH, SEQ, EMBED), (8, 16, 64)).spec == P("data")

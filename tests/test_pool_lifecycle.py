"""Ticket-lifecycle edges shared by both KV pool managers.

Both layouts must agree on the lifecycle contract the scheduler leans
on: ``release`` after a pressure preemption returns ``used_bytes`` to
EXACTLY zero (no leaked bytes/blocks — drift here compounds into
phantom pressure and spurious preemptions), and the ``can_admit``
empty-pool override admits a single over-budget prompt rather than
deadlocking the queue head forever.  Exercised through the real engine
too, so the override is proven to unstick an actual request.
"""
import dataclasses

import jax
import pytest

from repro.configs import registry
from repro.configs.base import ParallelConfig, RunConfig
from repro.models.api import get_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.pool import KVPoolManager, PagedKVPoolManager


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _pools(m, budget=None):
    return [KVPoolManager(m, 2, 64, byte_budget=budget),
            PagedKVPoolManager(m, 2, 64, byte_budget=budget,
                               block_size=16)]


TOKS = [list(range(1, 41)), [7, 8, 9]]


class TestReleaseAfterPreempt:
    def test_used_bytes_returns_to_exact_zero(self, setup):
        _, m, _ = setup
        for pool in _pools(m):
            for slot, toks in enumerate(TOKS):
                pool.allocate(slot, len(toks), tokens=toks)
                pool.positions[slot] = len(toks)   # as if inserted
                for t in (11, 12, 13):
                    pool.grow(slot, token=t)
            assert pool.used_bytes() > 0
            # preemption order: release victims youngest-first, then
            # drain the survivor — exactly what ServeEngine.step does
            for slot in (1, 0):
                pool.release(slot)
                assert pool.tickets[slot] < 0
            assert pool.used_bytes() == 0, type(pool).__name__
            assert pool.free_slots() == [0, 1]

    def test_budget_pressure_then_release_zeroes(self, setup):
        _, m, _ = setup
        for pool in _pools(m):
            unit = getattr(pool, "bytes_per_block", 0) or \
                pool.bytes_per_token * 16
            pool.byte_budget = int(unit * 2)
            for slot, toks in enumerate(TOKS):
                pool.allocate(slot, len(toks), tokens=toks)
                pool.positions[slot] = len(toks)
            victims = pool.pressure_victims()
            assert victims == [1], type(pool).__name__   # youngest
            for slot in victims:
                pool.release(slot)
            pool.release(0)
            assert pool.used_bytes() == 0, type(pool).__name__

    def test_paged_release_registers_then_rezeroes(self, setup):
        """The paged release publishes blocks to the radix; cold
        (registered, unreferenced) blocks must NOT count as used."""
        _, m, _ = setup
        pool = PagedKVPoolManager(m, 2, 64, block_size=16)
        toks = list(range(1, 41))
        pool.allocate(0, len(toks), tokens=toks)
        pool.positions[0] = len(toks)
        pool.release(0)
        assert pool.used_bytes() == 0
        assert pool.blocks.match_peek(toks) != []   # radix kept them
        # re-admission revives the cold blocks, release re-zeroes
        pool.allocate(0, len(toks), tokens=toks)
        assert pool.used_bytes() > 0
        pool.release(0)
        assert pool.used_bytes() == 0


class TestEmptyPoolOverride:
    def test_over_budget_prompt_admits_on_empty_pool(self, setup):
        _, m, _ = setup
        for pool in _pools(m, budget=1):        # nothing truly fits
            assert pool.can_admit(40, tokens=list(range(1, 41))), \
                type(pool).__name__
            pool.allocate(0, 40, tokens=list(range(1, 41)))
            # non-empty now: the same ask must be rejected
            assert not pool.can_admit(40, tokens=list(range(41, 81))), \
                type(pool).__name__

    def test_engine_drains_over_budget_queue(self, setup):
        """End to end: a queue of prompts, each alone over the byte
        budget, still drains one stream at a time — no deadlock."""
        run, _, params = setup
        for layout in ("slot", "paged"):
            eng = ServeEngine(run, params, slots=2, max_seq=64,
                              prefill_chunk=8, kv_layout=layout,
                              kv_byte_budget=1)
            reqs = [Request(uid=i, prompt=list(range(1, 20)),
                            max_new_tokens=4) for i in range(3)]
            for r in reqs:
                eng.add_request(r)
            eng.run_until_done()
            assert all(r.done for r in reqs), layout
            assert eng.pool.used_bytes() == 0, layout

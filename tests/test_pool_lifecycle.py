"""Ticket-lifecycle edges shared by both KV pool managers.

Both layouts must agree on the lifecycle contract the scheduler leans
on: ``release`` after a pressure preemption returns ``used_bytes`` to
EXACTLY zero (no leaked bytes/blocks — drift here compounds into
phantom pressure and spurious preemptions), and the ``can_admit``
empty-pool override admits a single over-budget prompt rather than
deadlocking the queue head forever.  Exercised through the real engine
too, so the override is proven to unstick an actual request.
"""
import dataclasses

import jax
import pytest

from repro.configs import registry
from repro.configs.base import ParallelConfig, RunConfig
from repro.models.api import get_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.pool import KVPoolManager, PagedKVPoolManager


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _pools(m, budget=None):
    return [KVPoolManager(m, 2, 64, byte_budget=budget),
            PagedKVPoolManager(m, 2, 64, byte_budget=budget,
                               block_size=16)]


TOKS = [list(range(1, 41)), [7, 8, 9]]


class TestReleaseAfterPreempt:
    def test_used_bytes_returns_to_exact_zero(self, setup):
        _, m, _ = setup
        for pool in _pools(m):
            for slot, toks in enumerate(TOKS):
                pool.allocate(slot, len(toks), tokens=toks)
                pool.positions[slot] = len(toks)   # as if inserted
                for t in (11, 12, 13):
                    pool.grow(slot, token=t)
            assert pool.used_bytes() > 0
            # preemption order: release victims youngest-first, then
            # drain the survivor — exactly what ServeEngine.step does
            for slot in (1, 0):
                pool.release(slot)
                assert pool.tickets[slot] < 0
            assert pool.used_bytes() == 0, type(pool).__name__
            assert pool.free_slots() == [0, 1]

    def test_budget_pressure_then_release_zeroes(self, setup):
        _, m, _ = setup
        for pool in _pools(m):
            unit = getattr(pool, "bytes_per_block", 0) or \
                pool.bytes_per_token * 16
            pool.byte_budget = int(unit * 2)
            for slot, toks in enumerate(TOKS):
                pool.allocate(slot, len(toks), tokens=toks)
                pool.positions[slot] = len(toks)
            victims = pool.pressure_victims()
            assert victims == [1], type(pool).__name__   # youngest
            for slot in victims:
                pool.release(slot)
            pool.release(0)
            assert pool.used_bytes() == 0, type(pool).__name__

    def test_paged_release_registers_then_rezeroes(self, setup):
        """The paged release publishes blocks to the radix; cold
        (registered, unreferenced) blocks must NOT count as used."""
        _, m, _ = setup
        pool = PagedKVPoolManager(m, 2, 64, block_size=16)
        toks = list(range(1, 41))
        pool.allocate(0, len(toks), tokens=toks)
        pool.positions[0] = len(toks)
        pool.release(0)
        assert pool.used_bytes() == 0
        assert pool.blocks.match_peek(toks) != []   # radix kept them
        # re-admission revives the cold blocks, release re-zeroes
        pool.allocate(0, len(toks), tokens=toks)
        assert pool.used_bytes() > 0
        pool.release(0)
        assert pool.used_bytes() == 0


class TestColdBlockAdmission:
    """Radix-matched blocks that are currently *cold* sit in
    ``free_capacity`` and outside ``used_bytes`` — but ``allocate``
    warms them.  Admission must charge for that transition on both the
    physical and the byte axis, or an admitted request exhausts the
    pool / overshoots the budget."""

    X = list(range(1, 41))               # 40 tokens -> 2 full blocks

    def _cold_prefix_pool(self, m, num_blocks):
        """A pool with X's 2 full blocks cold-cached and one live
        stream holding 2 referenced blocks."""
        pool = PagedKVPoolManager(m, 2, 64, block_size=16,
                                  num_blocks=num_blocks)
        pool.allocate(0, len(self.X), tokens=self.X)
        pool.positions[0] = len(self.X)
        pool.release(0)                  # 2 cold registered, 1 freed
        live = list(range(100, 117))     # 17 tokens -> 2 fresh blocks
        pool.allocate(0, len(live), tokens=live)
        pool.positions[0] = len(live)
        return pool

    def test_physical_gate_counts_cold_matched_blocks(self, setup):
        """4-block pool, 2 cold cached + 2 live: a 64-token prompt
        matching the cold prefix needs 2 fresh blocks AND removes the
        2 matched blocks from the recyclable set — impossible.  Pre-fix
        can_admit said yes and allocate() raised RuntimeError."""
        _, m, _ = setup
        pool = self._cold_prefix_pool(m, num_blocks=4)
        assert pool.blocks.free_capacity() == 2
        probe = self.X + list(range(200, 224))      # 64 tokens
        assert not pool.can_admit(len(probe), tokens=probe)
        # no over-rejection: a 17-token miss recycles the cold pair
        fresh = list(range(300, 317))
        assert pool.can_admit(len(fresh), tokens=fresh)
        pool.allocate(1, len(fresh), tokens=fresh)  # must not raise

    def test_byte_projection_counts_cold_matched_blocks(self, setup):
        """Matched cold blocks become referenced (-> used_bytes) at
        allocate; the projection must include them or admission
        overshoots the budget and leans on later preemption."""
        _, m, _ = setup
        pool = self._cold_prefix_pool(m, num_blocks=8)
        bpb = pool.bytes_per_block
        assert pool.used_bytes() == 2 * bpb
        probe = self.X + list(range(200, 224))      # 64 tokens
        # post-allocate: 2 live + 2 warmed + 2 fresh = 6 blocks
        pool.byte_budget = 5 * bpb
        assert not pool.can_admit(len(probe), tokens=probe)
        pool.byte_budget = 6 * bpb
        assert pool.can_admit(len(probe), tokens=probe)
        pool.allocate(1, len(probe), tokens=probe)
        assert pool.used_bytes() == 6 * bpb


class TestPressureSharedBlocks:
    def test_victim_estimate_counts_jointly_freed_blocks(self, setup):
        """A ref==2 block shared by two victims frees when the SECOND
        one is preempted; a static ref==1 snapshot never counts it, so
        the used-bytes estimate stays high and an extra stream (slot 1
        here) gets preempted beyond what the budget requires."""
        _, m, _ = setup
        pool = PagedKVPoolManager(m, 4, 64, block_size=16,
                                  num_blocks=16)
        shared = list(range(1, 33))          # 2 full blocks
        pool.allocate(2, len(shared), tokens=shared)   # throwaway:
        pool.positions[2] = len(shared)                # register the
        pool.release(2)                                # prefix cold
        for slot, toks in ((0, list(range(100, 110))),
                           (1, list(range(200, 210))),
                           (2, shared + [300]),
                           (3, shared + [301])):
            pool.allocate(slot, len(toks), tokens=toks)
            pool.positions[slot] = len(toks)
        bpb = pool.bytes_per_block
        assert pool.used_bytes() == 6 * bpb  # 1 + 1 + (2 shared + 1 + 1)
        pool.byte_budget = 2 * bpb
        # preempting 3 frees 1 block, then 2 frees 3 (its private one
        # plus the shared pair, now at ref 0) -> budget met, 1 survives
        assert pool.pressure_victims() == [3, 2]


class TestEmptyPoolOverride:
    def test_over_budget_prompt_admits_on_empty_pool(self, setup):
        _, m, _ = setup
        for pool in _pools(m, budget=1):        # nothing truly fits
            assert pool.can_admit(40, tokens=list(range(1, 41))), \
                type(pool).__name__
            pool.allocate(0, 40, tokens=list(range(1, 41)))
            # non-empty now: the same ask must be rejected
            assert not pool.can_admit(40, tokens=list(range(41, 81))), \
                type(pool).__name__

    def test_engine_drains_over_budget_queue(self, setup):
        """End to end: a queue of prompts, each alone over the byte
        budget, still drains one stream at a time — no deadlock."""
        run, _, params = setup
        for layout in ("slot", "paged"):
            eng = ServeEngine(run, params, slots=2, max_seq=64,
                              prefill_chunk=8, kv_layout=layout,
                              kv_byte_budget=1)
            reqs = [Request(uid=i, prompt=list(range(1, 20)),
                            max_new_tokens=4) for i in range(3)]
            for r in reqs:
                eng.add_request(r)
            eng.run_until_done()
            assert all(r.done for r in reqs), layout
            assert eng.pool.used_bytes() == 0, layout

"""CachePlan seam: plan contract per cache family, plan-derived byte
accounting, the int8 MLA latent family end to end, and the fused latent
decode kernel vs its oracle.

The load-bearing invariants:

* the plan (not hand-kept key lists) is the single source of truth for
  cache layout and bytes — pool accounting, the engine's
  ``kv_bytes_per_step``, and the analytic ``quant.kv`` formula all
  agree with it;
* an MLA stack serves end-to-end with ``kv_quantize="int8"``: greedy
  output == the f32-latent engine, chunked-prefill admission == whole
  prefill bit-exact, and the pool stays int8 throughout;
* the fused latent kernel matches the dequantize-then-attend oracle to
  1e-2 in interpret mode.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import LRDConfig, ModelConfig, ParallelConfig, \
    RunConfig
from repro.core import cost_model
from repro.kernels import ops, ref
from repro.layers import attention as attn
from repro.layers import cache as cache_mod
from repro.layers.param import ParamBuilder
from repro.models.api import get_model
from repro.quant import kv as kvq
from repro.serve.engine import Request, ServeEngine
from repro.serve.pool import KVPoolManager

# A dense-family MLA stack: chunked continuous admission applies (the
# MoE-family MLA configs keep blocking admission — expert capacity
# routing is not chunk-inert).
MLA_CFG = ModelConfig(
    name="mla-dense-tiny", family="dense", mla=True, num_layers=2,
    d_model=32, num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
    q_lora_rank=0, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
    v_head_dim=16, dtype="float32")

LONG = tuple((i * 7 + 3) % 50 + 1 for i in range(21))


@pytest.fixture(scope="module")
def mla_setup():
    run = RunConfig(model=MLA_CFG, parallel=ParallelConfig())
    m = get_model(MLA_CFG)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _serve(eng, prompts, n=6):
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


# ---------------------------------------------------------------------------
# Plan contract: family / leaves / bytes per config
# ---------------------------------------------------------------------------

class TestPlanContract:
    def test_gqa_f32(self):
        plan = cache_mod.gqa_plan(2, 8, jnp.float32)
        assert plan.family == "gqa_f32"
        assert not plan.quantized and not plan.mla
        assert {l.name for l in plan.leaves} == {"k", "v"}
        assert plan.bytes_per_token == 2 * 2 * 8 * 4
        assert plan.bytes_per_slot == 0
        assert plan.spec(3, 16) == attn.kv_cache_spec(3, 16, 2, 8,
                                                      jnp.float32)

    def test_gqa_int8(self):
        plan = cache_mod.gqa_plan(2, 8, jnp.float32, "int8")
        assert plan.family == "gqa_int8"
        assert plan.quantized
        assert plan.quant_pairs == {"k_q": "k_scale", "v_q": "v_scale"}
        assert plan.bytes_per_token == 2 * 2 * 8          # int8 values
        assert plan.bytes_per_slot == 2 * 2 * 8 * 4       # f32 scale rows
        assert plan.spec(3, 16) == kvq.kv_cache_spec_q(3, 16, 2, 8)

    def test_mla_latent(self):
        plan = cache_mod.mla_plan(16, 8, jnp.float32)
        assert plan.family == "mla_latent"
        assert plan.mla and not plan.quantized
        assert plan.bytes_per_token == (16 + 8) * 4
        assert plan.spec(1, 32) == attn.mla_cache_spec(1, 32, MLA_CFG,
                                                       jnp.float32)

    def test_mla_latent_int8(self):
        plan = cache_mod.mla_plan(16, 8, jnp.float32, "int8")
        assert plan.family == "mla_latent_int8"
        assert plan.quant_pairs == {"ckv_q": "ckv_scale",
                                    "krope_q": "krope_scale"}
        assert plan.bytes_per_token == 16 + 8
        assert plan.bytes_per_slot == (16 + 8) * 4
        spec = plan.spec(2, 32)
        assert spec["ckv_q"] == jax.ShapeDtypeStruct((2, 32, 16), jnp.int8)
        assert spec["ckv_scale"] == jax.ShapeDtypeStruct((2, 16),
                                                         jnp.float32)
        init = plan.init(2, 32)
        assert init["krope_q"].dtype == jnp.int8
        # zero scales dequantize the zero pool to exact zeros
        assert float(jnp.abs(kvq.dequantize_kv(
            init["ckv_q"], init["ckv_scale"])).max()) == 0.0

    def test_bytes_per_step_matches_analytic_gqa(self):
        """The plan's pool-read figure == the analytic quant.kv formula
        (the plan is the source of truth; the formula is the GQA twin)."""
        for mode, dtype_bytes in ((None, 4), ("int8", 4)):
            plan = cache_mod.gqa_plan(2, 64, jnp.float32, mode)
            assert plan.bytes_per_step(4, 64) == kvq.kv_bytes_per_step(
                4, 64, 2, 64, quantize=mode, dtype_bytes=dtype_bytes)

    def test_build_from_config_and_cache(self):
        gqa_cfg = registry.get("llama3.2-1b").smoke
        plan = cache_mod.build_cache_plan(gqa_cfg, jnp.float32, "int8")
        assert plan.family == "gqa_int8"
        assert cache_mod.build_cache_plan(MLA_CFG, jnp.float32,
                                          "int8").family == "mla_latent_int8"
        # plan_from_cache round-trips every family from its leaves
        for cfg, quant in ((gqa_cfg, None), (gqa_cfg, "int8"),
                           (MLA_CFG, None), (MLA_CFG, "int8")):
            p = cache_mod.build_cache_plan(cfg, jnp.float32, quant)
            assert cache_mod.plan_from_cache(p.init(1, 8),
                                             jnp.float32) is p

    def test_unknown_mode_and_cache_raise(self):
        with pytest.raises(ValueError):
            cache_mod.gqa_plan(2, 8, jnp.float32, "int4")
        with pytest.raises(ValueError):
            cache_mod.plan_from_cache({"state": jnp.zeros((1, 2))})

    def test_executor_family_guards(self):
        gqa = cache_mod.gqa_plan(2, 8, jnp.float32)
        mla = cache_mod.mla_plan(16, 8, jnp.float32)
        q = jnp.zeros((1, 1, 4, 8))
        with pytest.raises(ValueError):
            mla.attend_decode(q, mla.init(1, 8), jnp.zeros((1,), jnp.int32))
        with pytest.raises(ValueError):
            gqa.attend_decode_latent(q, q, gqa.init(1, 8),
                                     jnp.zeros((1,), jnp.int32), scale=1.0)


class TestPlanDerivedAccounting:
    def test_pool_bytes_from_plans(self, mla_setup):
        run, m, params = mla_setup
        for mode in (None, "int8"):
            pool = KVPoolManager(m, 2, 32, kv_quantize=mode)
            plan = m.cache_plan(mode)
            assert len(pool.plans) == MLA_CFG.num_layers
            assert pool.bytes_per_token \
                == MLA_CFG.num_layers * plan.bytes_per_token
            assert pool.kv_bytes_per_step \
                == MLA_CFG.num_layers * plan.bytes_per_step(2, 32)

    def test_latent_bytes_counted_not_undercounted(self, mla_setup):
        """The old hand-kept key walk is gone: the engine's roofline
        figure comes from the plans and covers the latent leaves."""
        run, m, params = mla_setup
        eng = ServeEngine(run, params, slots=2, max_seq=32)
        assert eng.plan_summary["kv_bytes_per_step"] \
            == eng.pool.kv_bytes_per_step > 0
        assert eng.plan_summary["kv_cache_family"] == "mla_latent"
        eng_q = ServeEngine(run, params, slots=2, max_seq=32,
                            kv_quantize="int8")
        assert eng_q.plan_summary["kv_cache_family"] == "mla_latent_int8"
        ratio = (eng.plan_summary["kv_bytes_per_step"]
                 / eng_q.plan_summary["kv_bytes_per_step"])
        assert ratio >= 3.0      # ~4x values, minus the f32 scale rows

    def test_cost_model_kv_bytes_from_plan(self):
        plan = cache_mod.gqa_plan(2, 64, jnp.float32, "int8")
        assert cost_model.plan_kv_bytes(plan, 4, 64) \
            == plan.bytes_per_step(4, 64) \
            == kvq.kv_bytes_per_step(4, 64, 2, 64, quantize="int8")

    def test_ssm_has_no_plans(self):
        cfg = registry.get("mamba2-2.7b").smoke
        m = get_model(cfg)
        assert m.cache_plans() == []
        pool = KVPoolManager(m, 1, 16)
        assert pool.bytes_per_token == 0 and pool.kv_bytes_per_step == 0


# ---------------------------------------------------------------------------
# Latent write primitives (quant/kv reused on (B, S, r) leaves)
# ---------------------------------------------------------------------------

class TestLatentWrites:
    def test_write_token_latent_round_trip(self, rng):
        b, s, r = 2, 12, 16
        x = jax.random.normal(rng, (b, s, r), jnp.float32)
        cache = jnp.zeros((b, s, r), jnp.int8)
        scale = jnp.zeros((b, r), jnp.float32)
        for t in range(s):
            cache, scale = kvq.kv_write_token(
                cache, scale, x[:, t], jnp.full((b,), t, jnp.int32))
        _, scale_ref = kvq.quantize_kv_prefill(x)
        np.testing.assert_allclose(np.asarray(scale),
                                   np.asarray(scale_ref), rtol=1e-6)
        back = kvq.dequantize_kv(cache, scale)
        bound = jnp.broadcast_to(1.5 * scale[:, None] + 1e-8, x.shape)
        assert bool(jnp.all(jnp.abs(back - x) <= bound))

    def test_quantize_kv_tree_latent_stacked(self, rng):
        """Stacked (L, 1, S, r) latent staging caches quantize with the
        seq reduction on the right axis and the pad tail masked."""
        ckv = jax.random.normal(rng, (3, 1, 8, 16), jnp.float32)
        krope = jax.random.normal(jax.random.fold_in(rng, 1),
                                  (3, 1, 8, 4), jnp.float32)
        got = kvq.quantize_kv_tree({"blocks": {"ckv": ckv, "krope": krope}},
                                   jnp.asarray(5))["blocks"]
        assert got["ckv_q"].shape == (3, 1, 8, 16)
        assert got["ckv_scale"].shape == (3, 1, 16)
        assert got["krope_q"].dtype == jnp.int8
        assert int(jnp.abs(got["ckv_q"][:, :, 5:]
                           .astype(jnp.int32)).max()) == 0
        # masked quantization == plan.write_prefill quantize-on-insert
        plan = cache_mod.mla_plan(16, 4, jnp.float32, "int8")
        want = plan.write_prefill(plan.init(1, 8),
                                  {"ckv": ckv[0], "krope": krope[0]},
                                  jnp.asarray(5))
        np.testing.assert_array_equal(np.asarray(got["ckv_q"][0]),
                                      np.asarray(want["ckv_q"]))
        np.testing.assert_array_equal(np.asarray(got["ckv_scale"][0]),
                                      np.asarray(want["ckv_scale"]))

    def test_write_chunk_latent_matches_token_loop_scale(self, rng):
        cache = jnp.zeros((1, 16, 8), jnp.int8)
        scale = jnp.zeros((1, 8), jnp.float32)
        new = jax.random.normal(rng, (1, 5, 8), jnp.float32)
        _, sc = kvq.kv_write_chunk(cache, scale, new, jnp.asarray(3))
        ct, st = cache, scale
        for t in range(5):
            ct, st = kvq.kv_write_token(ct, st, new[:, t],
                                        jnp.full((1,), 3 + t, jnp.int32))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(st))


# ---------------------------------------------------------------------------
# Fused latent decode kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------

LATENT_SHAPES = [
    # b, s, h, lora, rope, bs
    (2, 64, 4, 32, 16, 32),       # multi-block online softmax
    (3, 100, 2, 16, 8, 64),       # unaligned S -> padding path
    (1, 16, 8, 64, 8, 128),       # S smaller than one block
]


class TestLatentKernel:
    def _mk(self, rng, b, s, h, lora, rope):
        ks = jax.random.split(jax.random.fold_in(rng, b * s + h), 5)
        q_lat = jax.random.normal(ks[0], (b, 1, h, lora), jnp.float32) * 0.5
        q_rope = jax.random.normal(ks[1], (b, 1, h, rope), jnp.float32) * 0.5
        cq, cs = kvq.quantize_kv_prefill(
            jax.random.normal(ks[2], (b, s, lora), jnp.float32))
        rq, rs = kvq.quantize_kv_prefill(
            jax.random.normal(ks[3], (b, s, rope), jnp.float32))
        pos = jax.random.randint(ks[4], (b,), 1, s - 1)
        return q_lat, q_rope, cq, cs, rq, rs, pos

    @pytest.mark.parametrize("b,s,h,lora,rope,bs", LATENT_SHAPES)
    def test_kernel_matches_ref(self, b, s, h, lora, rope, bs, rng):
        args = self._mk(rng, b, s, h, lora, rope)
        scale = 1.0 / ((lora + rope) ** 0.5)
        got = ops.decode_attention_latent_q(*args, scale=scale, bs=bs,
                                            force_kernel=True)
        want = ref.decode_attention_latent_q_ref(*args, scale=scale)
        assert got.shape == want.shape == (b, 1, h, lora)
        assert float(jnp.abs(got - want).max()) <= 1e-2

    def test_ref_matches_f32_latent_attention(self, rng):
        """The oracle == the plan's f32 latent attend run on the
        dequantized pool (same masking semantics)."""
        q_lat, q_rope, cq, cs, rq, rs, pos = self._mk(rng, 2, 32, 4, 16, 8)
        scale = 0.2
        got = ref.decode_attention_latent_q_ref(
            q_lat, q_rope, cq, cs, rq, rs, pos, scale=scale)
        plan = cache_mod.mla_plan(16, 8, jnp.float32)
        want = plan.attend_decode_latent(
            q_lat, q_rope,
            {"ckv": kvq.dequantize_kv(cq, cs),
             "krope": kvq.dequantize_kv(rq, rs)}, pos, scale=scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

    def test_vmem_fallback_dispatch(self):
        assert ops.kernel_fits("decode_latent_q", 4, c=512, s=128, r=128,
                               r1=64)
        assert not ops.kernel_fits("decode_latent_q", 4, c=65536, s=128,
                                   r=4096, r1=64, bn=4096)


# ---------------------------------------------------------------------------
# MLA serving end to end: int8 latents, chunked admission
# ---------------------------------------------------------------------------

class TestMLAServeInt8:
    def test_int8_latent_greedy_matches_f32(self, mla_setup):
        run, m, params = mla_setup
        eng_f = ServeEngine(run, params, slots=2, max_seq=64)
        out_f = _serve(eng_f, [LONG, (4, 5, 6)])
        eng_q = ServeEngine(run, params, slots=2, max_seq=64,
                            kv_quantize="int8")
        out_q = _serve(eng_q, [LONG, (4, 5, 6)])
        assert out_f == out_q
        leaves = jax.tree_util.tree_flatten_with_path(eng_q.cache)[0]
        dtypes = {str(getattr(p[-1], "key", p[-1])): l.dtype
                  for p, l in leaves}
        assert dtypes["ckv_q"] == jnp.int8
        assert dtypes["krope_q"] == jnp.int8
        assert dtypes["ckv_scale"] == jnp.float32

    @pytest.mark.parametrize("kvq_mode", [None, "int8"])
    def test_chunked_equals_whole(self, mla_setup, kvq_mode):
        """MLA stacks take continuous admission now (PR 4 gated them);
        chunked greedy == whole-prefill greedy bit-exact, both pool
        dtypes — the staging cache stays f32, the pool quantizes once
        at insert."""
        run, m, params = mla_setup
        eng_b = ServeEngine(run, params, slots=2, max_seq=64,
                            admission="blocking", kv_quantize=kvq_mode)
        out_b = _serve(eng_b, [LONG, (4, 5, 6)])
        eng_c = ServeEngine(run, params, slots=2, max_seq=64,
                            admission="continuous", prefill_chunk=8,
                            kv_quantize=kvq_mode)
        out_c = _serve(eng_c, [LONG, (4, 5, 6)])
        assert out_b == out_c
        # chunking actually happened: 21-token prompt, 8-token chunks
        assert max(s["prefill_tokens"] for s in eng_c.stats) <= 8 + 3

    def test_continuous_is_default_for_dense_mla(self, mla_setup):
        run, m, params = mla_setup
        eng = ServeEngine(run, params, slots=1, max_seq=32)
        assert eng.admission == "continuous"

    def test_moe_mla_keeps_blocking(self):
        """Expert-capacity routing is not chunk-inert: the MoE-family
        MLA config (deepseek) still refuses continuous admission."""
        cfg = registry.get("deepseek-v2-236b").smoke
        run = RunConfig(model=cfg, parallel=ParallelConfig())
        m = get_model(cfg)
        params, _ = m.init(jax.random.PRNGKey(0))
        eng = ServeEngine(run, params, slots=1, max_seq=32)
        assert eng.admission == "blocking"
        with pytest.raises(ValueError):
            ServeEngine(run, params, slots=1, max_seq=32,
                        admission="continuous")

    def test_matches_full_forward_reference(self, mla_setup):
        run, m, params = mla_setup
        eng = ServeEngine(run, params, slots=2, max_seq=64,
                          kv_quantize="int8", prefill_chunk=8)
        (out,) = _serve(eng, [LONG], n=5)
        toks = list(LONG)
        for _ in range(5):
            x, _ = m.forward(params, {"tokens": jnp.asarray([toks])})
            logits = m.logits(params, x)
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert out == toks[len(LONG):]

    def test_use_pallas_matches_ref_path(self, mla_setup):
        """lrd.use_pallas routes int8 latent decode through the fused
        kernel (interpret mode on CPU) — outputs match the oracle."""
        run, m, params = mla_setup
        run_k = dataclasses.replace(run, lrd=LRDConfig(use_pallas=True))
        eng_r = ServeEngine(run, params, slots=1, max_seq=32,
                            kv_quantize="int8")
        out_r = _serve(eng_r, [(1, 2, 3)], n=3)
        eng_k = ServeEngine(run_k, params, slots=1, max_seq=32,
                            kv_quantize="int8")
        out_k = _serve(eng_k, [(1, 2, 3)], n=3)
        assert out_r == out_k

    def test_lrd_config_knob(self, mla_setup):
        run, m, params = mla_setup
        run_q = dataclasses.replace(
            run, lrd=dataclasses.replace(LRDConfig(), kv_quantize="int8"))
        eng = ServeEngine(run_q, params, slots=1, max_seq=32)
        assert eng.kv_quantize == "int8"
        assert eng.plan_summary["kv_cache_family"] == "mla_latent_int8"


# ---------------------------------------------------------------------------
# attention.py executes through the plan (no raw key branches left)
# ---------------------------------------------------------------------------

class TestAttentionIsThinExecutor:
    def test_no_cache_key_sniffing_in_attention(self):
        """The acceptance bar: every cache-layout dispatch goes through
        CachePlan; attention.py no longer inspects cache keys."""
        import inspect
        import repro.layers.attention as attention
        src = inspect.getsource(attention)
        for pattern in ('"k_q" in', "'k_q' in", '"ckv" in', "'ckv' in",
                        'is_quantized_kv', 'cache["k_q"]', 'cache["ckv"]'):
            assert pattern not in src, pattern

    def test_explicit_plan_equals_derived(self, rng):
        """Threading the plan explicitly (the serve runner's path) and
        deriving it from cache keys produce identical results."""
        pb = ParamBuilder(rng, jnp.float32)
        attn.init_attention(pb, "a", 32, 4, 2, 8)
        p = pb.params["a"]
        x = jax.random.normal(jax.random.fold_in(rng, 2), (1, 4, 32),
                              jnp.float32)
        kw = dict(num_heads=4, num_kv_heads=2, head_dim=8, rope_theta=1e4,
                  positions=jnp.arange(4)[None, :])
        plan = cache_mod.gqa_plan(2, 8, jnp.float32, "int8")
        outs = []
        for explicit in (None, plan):
            cache = attn.init_kv_cache(1, 8, 2, 8, jnp.float32, "int8")
            o, c = attn.apply_attention(p, x, cache=cache, plan=explicit,
                                        **kw)
            outs.append((o, c))
        np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                      np.asarray(outs[1][0]))
        np.testing.assert_array_equal(np.asarray(outs[0][1]["k_q"]),
                                      np.asarray(outs[1][1]["k_q"]))

    def test_mla_padded_chunk_rows_masked_at_write(self, rng):
        """Bucket-padded MLA chunks zero pad-row latents at the write
        (prompt_len = the chunk's real end), mirroring the GQA path —
        required now that the scheduler chunks dense MLA stacks."""
        pb = ParamBuilder(rng, jnp.float32)
        attn.init_mla(pb, "mla", MLA_CFG)
        p = pb.params["mla"]
        s, s_max = 12, 32
        x = jax.random.normal(jax.random.fold_in(rng, 7), (1, s, 32),
                              jnp.float32) * 0.3
        garbage = jnp.full((1, 3, 32), 7.7, jnp.float32)
        whole = attn.init_mla_cache(1, s_max, MLA_CFG, jnp.float32)
        _, c_whole = attn.apply_mla(p, x, MLA_CFG,
                                    positions=jnp.arange(s)[None, :],
                                    cache=whole)
        cache = attn.init_mla_cache(1, s_max, MLA_CFG, jnp.float32)
        _, cache = attn.apply_mla(
            p, jnp.concatenate([x[:, :5], garbage], 1), MLA_CFG,
            positions=jnp.arange(8)[None, :], cache=cache,
            start_pos=jnp.asarray(0), prompt_len=jnp.asarray(5))
        assert float(jnp.abs(cache["ckv"][:, 5:8]).max()) == 0.0
        _, cache = attn.apply_mla(
            p, x[:, 5:], MLA_CFG, positions=5 + jnp.arange(7)[None, :],
            cache=cache, start_pos=jnp.asarray(5),
            prompt_len=jnp.asarray(s))
        np.testing.assert_allclose(np.asarray(cache["ckv"][:, :s]),
                                   np.asarray(c_whole["ckv"][:, :s]),
                                   atol=1e-6, rtol=1e-6)

"""MoE dispatch: global vs hierarchical (grouped) equivalence, capacity
semantics, and vocab padding (§Perf optimizations)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.models.api import get_model, synth_inputs

SHAPE = ShapeConfig("smoke", 64, 4, "train")


class TestGroupedDispatch:
    def _cfgs(self):
        base = dataclasses.replace(registry.get("moonshot-v1-16b-a3b").smoke,
                                   moe_capacity_factor=8.0)
        grouped = dataclasses.replace(base, moe_dispatch_groups=4)
        return base, grouped

    def test_grouped_matches_global_with_headroom(self):
        """With generous capacity both dispatches route every token ->
        same function (up to bf16 noise)."""
        base, grouped = self._cfgs()
        m1, m2 = get_model(base), get_model(grouped)
        params, _ = m1.init(jax.random.PRNGKey(0))
        batch = synth_inputs(base, SHAPE, jax.random.PRNGKey(1))
        l1, _ = m1.loss(params, batch)
        l2, _ = m2.loss(params, batch)
        assert abs(float(l1) - float(l2)) < 5e-3

    def test_grouped_gradients_flow(self):
        base, grouped = self._cfgs()
        m = get_model(grouped)
        params, _ = m.init(jax.random.PRNGKey(0))
        batch = synth_inputs(grouped, SHAPE, jax.random.PRNGKey(1))
        g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
        gnorm = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
        assert np.isfinite(gnorm) and gnorm > 0
        # expert weights receive gradient
        ew = g["blocks"]["moe"]["experts"]["up"]["w"]
        assert float(jnp.abs(ew).max()) > 0

    def test_group_capacity_is_local(self):
        """Group capacity derives from group token count, not global."""
        from repro.layers.moe import apply_moe
        from repro.layers.param import ParamBuilder
        from repro.layers.moe import init_moe
        pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
        init_moe(pb, "moe", 16, 32, num_experts=4, num_shared=0)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
        y_g, aux_g = apply_moe(pb.params["moe"], x, top_k=2,
                               capacity_factor=1.25, dispatch_groups=2)
        y, aux = apply_moe(pb.params["moe"], x, top_k=2,
                           capacity_factor=1.25)
        assert y_g.shape == y.shape
        assert np.isfinite(float(aux_g))

    def test_fallback_when_indivisible(self):
        """Groups that don't divide the token count fall back to global."""
        from repro.layers.moe import apply_moe, init_moe
        from repro.layers.param import ParamBuilder
        pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
        init_moe(pb, "moe", 16, 32, num_experts=4, num_shared=0)
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 11, 16))
        y, _ = apply_moe(pb.params["moe"], x, top_k=2,
                         capacity_factor=2.0, dispatch_groups=7)
        assert y.shape == x.shape


class TestVocabPadding:
    def test_padded_table_and_masked_logits(self):
        cfg = dataclasses.replace(registry.get("mamba2-2.7b").smoke,
                                  vocab_size=250)
        m = get_model(cfg)
        assert m.padded_vocab == 256
        params, _ = m.init(jax.random.PRNGKey(0))
        assert params["embed"]["w"].shape[0] == 256
        batch = synth_inputs(cfg, SHAPE, jax.random.PRNGKey(1))
        x, _ = m.forward(params, batch)
        logits = m.logits(params, x)
        # padded columns can never win
        assert int(jnp.argmax(logits, -1).max()) < 250
        assert float(logits[..., 250:].max()) < -1e29
        loss, _ = m.loss(params, batch)
        assert abs(float(loss) - np.log(250)) < 0.5

    def test_no_padding_when_aligned(self):
        cfg = registry.get("llama3.2-1b").smoke       # vocab 256
        m = get_model(cfg)
        assert m.padded_vocab == cfg.vocab_size

    def test_opt_out(self):
        cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                                  vocab_size=250, pad_vocab=False)
        m = get_model(cfg)
        params, _ = m.init(jax.random.PRNGKey(0))
        assert params["embed"]["w"].shape[0] == 250

"""Algorithm 1 (paper §2.1), the TPU cost model, and rank alignment."""
import jax
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cost_model as cm
from repro.core import rank_selection as rs


class TestCostModel:
    def test_staircase(self):
        """t(r) under the MXU model is flat within a 128-tile and jumps
        across the boundary — the paper's Fig. 2 cliff."""
        t = cm.make_model_timer(4096, 2048, 8192)
        # within one tile row (compute-bound regime): flat in padded dim
        assert t(300) == pytest.approx(t(384), rel=0.02)
        # across the boundary: strictly cheaper
        assert t(256) < t(257)

    def test_dense_vs_lowrank_crossover(self):
        """Big FC layers win from LRD; tiny layers don't (paper's ORG)."""
        big = cm.lowrank_layer_time(4096, 4096, 16384, 1024)
        assert big < cm.dense_layer_time(4096, 4096, 16384)
        small = cm.lowrank_layer_time(4096, 256, 256, 64)
        assert small > cm.dense_layer_time(4096, 256, 256) * 0.9

    def test_branched_core_shrinks_time(self):
        base = cm.branched_layer_time(4096, 2048, 2048, 1024, 1024, 1)
        branched = cm.branched_layer_time(4096, 2048, 2048, 1024, 1024, 4)
        assert branched < base


class TestAlgorithm1:
    def test_finds_tile_boundary(self):
        """On the stepwise cost model the search returns an MXU-aligned
        rank (the closed-form align_rank shortcut is provably what the
        paper's search finds on TPU)."""
        m, c, s = 4096, 2048, 8192
        timer = cm.make_model_timer(m, c, s)
        dec = rs.algorithm1(timer, cm.make_dense_time(m, c, s), 1309, 300)
        assert dec.rank % 128 == 0
        assert dec.rank == rs.align_rank(1309, 128)

    def test_org_when_dense_faster(self):
        """Memory-bound small layer: decomposition never wins -> ORG
        (paper Table 2, layer1.0.conv1)."""
        m, c, s = 4096, 512, 512
        timer = cm.make_model_timer(m, c, s)
        dec = rs.algorithm1(timer, cm.make_dense_time(m, c, s), 128, 32)
        assert dec.keep_original

    def test_speedup_reported(self):
        m, c, s = 4096, 4096, 16384
        timer = cm.make_model_timer(m, c, s)
        dec = rs.algorithm1(timer, cm.make_dense_time(m, c, s), 1024, 256)
        assert not dec.keep_original
        assert dec.speedup() > 1.0

    @given(rank=st.integers(1, 4096))
    @settings(max_examples=60, deadline=None)
    def test_align_rank_properties(self, rank):
        r = rs.align_rank(rank, 128)
        assert r >= 8
        assert r <= max(rank, 8)
        if rank >= 128:
            assert r % 128 == 0

    def test_select_rank_modes(self):
        r_ratio = rs.select_rank(2048, 8192, compression=2.0, mode="ratio")
        r_aligned = rs.select_rank(2048, 8192, compression=2.0,
                                   mode="aligned")
        assert r_aligned % 128 == 0
        assert r_aligned <= r_ratio
        r_search = rs.select_rank(2048, 8192, compression=2.0, mode="search")
        assert r_search == rs.ORG or r_search % 8 == 0

    def test_max_branches_guard(self):
        assert rs.max_branches(1024) == 8
        assert rs.max_branches(100) == 1

"""Branched LRD (paper §2.4, Eq. 12-20) and layer merging (§2.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import branching, merging, svd, tucker


class TestBranching:
    def test_fc_branching_exact_at_init(self, rng):
        """For FC layers the SVD 'core' is diagonal, so the block-diagonal
        truncation loses nothing: branched == rank-r SVD exactly."""
        w = jax.random.normal(rng, (64, 48))
        for n in (1, 2, 4):
            bf = branching.branch_svd(w, 32, n)
            f = svd.svd_decompose(w, 32)
            np.testing.assert_allclose(
                np.asarray(branching.reconstruct(bf)),
                np.asarray(f.w0 @ f.w1), atol=1e-4)

    def test_batched_branch_svd(self, rng):
        w = jax.random.normal(rng, (3, 64, 48))
        bf = branching.branch_svd(w, 16, 4)
        assert bf.u.shape == (3, 4, 64, 4)
        assert bf.xc.shape == (3, 4, 4, 4)
        assert bf.v.shape == (3, 4, 4, 48)

    def test_tucker_branch_param_savings(self):
        """Eq. 18-20: the branched core is N x smaller."""
        c, s, k, r1, r2 = 256, 256, 3, 128, 128
        base = tucker.tucker2_params(c, s, k, r1, r2)
        for n in (2, 4):
            got = branching.branched_conv_params(c, s, k, r1, r2, n)
            core_saving = r1 * r2 * k * k * (1 - 1 / n)
            assert base - got == pytest.approx(core_saving, rel=1e-6)

    def test_tucker_branching_error_bounded(self, rng):
        """Branching truncates off-diagonal core blocks: error grows with
        N but stays below the rank-truncation error of an equivalent
        parameter budget only for structured tensors; here we just assert
        monotonicity + sanity."""
        w = jax.random.normal(rng, (3, 3, 32, 32))
        errs = []
        for n in (1, 2, 4):
            f = branching.branch_tucker(w, 16, 16, n)
            errs.append(branching.branch_error(w, f))
        assert errs[0] <= errs[1] <= errs[2] + 1e-6
        assert errs[2] < 1.0

    def test_quantize_ranks(self):
        assert branching.quantize_ranks(300, 300, 4) == (300, 300)
        assert branching.quantize_ranks(301, 303, 4) == (304, 304)


class TestMerging:
    def test_merge_linear_exact(self, rng):
        a = jax.random.normal(rng, (32, 8))
        b = jax.random.normal(jax.random.fold_in(rng, 1), (8, 24))
        np.testing.assert_allclose(np.asarray(merging.merge_linear(a, b)),
                                   np.asarray(a @ b), atol=1e-5)

    def test_conv1x1_merges(self, rng):
        k1, k2 = jax.random.split(rng)
        conv1 = jax.random.normal(k1, (1, 1, 16, 32))
        u = jax.random.normal(k2, (32, 8))
        merged = merging.merge_conv1x1_into_u(conv1, u)
        assert merged.shape == (1, 1, 16, 8)
        np.testing.assert_allclose(
            np.asarray(merged[0, 0]), np.asarray(conv1[0, 0] @ u), atol=1e-5)

    def test_merged_attention_full_rank_recovers_products(self, rng):
        """At qk_rank >= head_dim * heads the joint factorization is exact
        on the QK^T and V O products."""
        d, h, hd = 32, 4, 8
        ks = jax.random.split(rng, 4)
        wq = jax.random.normal(ks[0], (d, h * hd)) * 0.1
        wk = jax.random.normal(ks[1], (d, h * hd)) * 0.1
        wv = jax.random.normal(ks[2], (d, h * hd)) * 0.1
        wo = jax.random.normal(ks[3], (h * hd, d)) * 0.1
        f = merging.merge_attention(wq, wk, wv, wo, num_heads=h,
                                    qk_rank=d, vo_rank=d)
        e_qk, e_vo = merging.merged_attention_error(wq, wk, wv, wo, f, h)
        assert e_qk < 1e-4 and e_vo < 1e-4

    def test_merged_attention_lowrank_params(self):
        """Savings regime: rank < head_dim (the per-head aq/bo factors are
        d*H*rank, vs the dense d*H*head_dim)."""
        d, h, hd = 4096, 32, 128
        dense = merging.dense_attention_params(d, h, h, hd)
        merged = merging.merged_attention_params(d, h, 64, 64)
        assert merged < dense // 2
        # KV-cache win is rank-vs-heads*head_dim regardless:
        # cache/token = qk_rank + vo_rank << 2*h*hd

    def test_merged_error_decreases_with_rank(self, rng):
        d, h, hd = 24, 2, 8
        ks = jax.random.split(rng, 4)
        wq, wk, wv = (jax.random.normal(k, (d, h * hd)) for k in ks[:3])
        wo = jax.random.normal(ks[3], (h * hd, d))
        errs = []
        for r in (4, 12, 24):
            f = merging.merge_attention(wq, wk, wv, wo, num_heads=h,
                                        qk_rank=r, vo_rank=r)
            errs.append(merging.merged_attention_error(wq, wk, wv, wo,
                                                       f, h)[0])
        assert errs[0] >= errs[1] >= errs[2]


class TestResNetMerging:
    def test_bottleneck_merge_restores_layer_count(self, rng):
        """Paper §2.3/Table 3: merged model has exactly the original layer
        count with fewer params."""
        from repro.configs import registry
        from repro.configs.base import LRDConfig
        from repro.core.surgery import decompose_model
        from repro.models.resnet import ResNetModel, merge_bottleneck

        cfg = registry.get("resnet50").smoke
        m = ResNetModel(cfg)
        params, axes = m.init(rng)
        n_orig = m.layer_count(params)
        # decompose ONLY 3x3 convs (merging mode decomposes the cores)
        lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="ratio",
                        min_dim=8, targets=("conv",))
        p2, _, _ = decompose_model(params, axes, lrd)
        exclude_1x1 = m.layer_count(p2)
        assert exclude_1x1 > n_orig          # vanilla LRD is deeper
        merged = merge_bottleneck(p2)
        assert m.layer_count(merged) == n_orig
        n_params = sum(x.size for x in jax.tree.leaves(merged))
        assert n_params < sum(x.size for x in jax.tree.leaves(params))
        # and it still runs
        imgs = jax.random.normal(rng, (2, cfg.img_size, cfg.img_size, 3))
        out = m.forward(merged, imgs)
        assert out.shape == (2, cfg.num_classes)
        assert not bool(jnp.any(jnp.isnan(out)))

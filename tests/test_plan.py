"""LinearPlan: classification, kernel eligibility, accounting, execution.

The plan is the one seam every consumer dispatches through; these tests
pin its contract — including the satellite fix that decode-shaped
``(B, 1, d)`` activations reach the fused kernels (the old
``x.ndim == 2`` gate is gone).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import plan as lplan
from repro.layers.param import (apply_linear, linear_flops, linear_out_dim,
                                linear_param_count, linear_quant_bytes)
from repro.quant import quantize_tree


def _lowrank(rng, c=128, r=32, s=64):
    ks = jax.random.split(rng, 2)
    return {"w0": jax.random.normal(ks[0], (c, r)) * 0.1,
            "w1": jax.random.normal(ks[1], (r, s)) * 0.1}


def _branched(rng, n=4, c=128, r1=16, r2=16, s=64):
    ks = jax.random.split(rng, 3)
    return {"u": jax.random.normal(ks[0], (n, c, r1)) * 0.1,
            "xc": jax.random.normal(ks[1], (n, r1, r2)) * 0.1,
            "v": jax.random.normal(ks[2], (n, r2, s)) * 0.1}


class TestClassification:
    def test_kinds(self, rng):
        assert lplan.build_plan({"w": jnp.zeros((8, 16))}).kind == "dense"
        assert lplan.build_plan(_lowrank(rng)).kind == "lowrank"
        assert lplan.build_plan(_branched(rng)).kind == "branched"
        tk = {"tucker_u": jnp.zeros((16, 4)), "core": jnp.zeros((3, 3, 4, 4)),
              "tucker_v": jnp.zeros((4, 16))}
        assert lplan.build_plan(tk).kind == "tucker_conv"
        bt = {"u": jnp.zeros((2, 16, 4)), "core": jnp.zeros((2, 3, 3, 4, 4)),
              "v": jnp.zeros((2, 4, 16))}
        assert lplan.build_plan(bt).kind == "branched_tucker_conv"

    def test_quantized_trees_keep_kind(self, rng):
        for tree, kind in ((_lowrank(rng), "lowrank"),
                           (_branched(rng), "branched")):
            plan = lplan.build_plan(quantize_tree(tree))
            assert plan.kind == kind
            assert plan.fully_quantized and plan.quantized

    def test_partial_quant_is_not_fully_quantized(self, rng):
        plan = lplan.build_plan(quantize_tree(_lowrank(rng),
                                              targets=("w0",)))
        assert plan.quantized and not plan.fully_quantized

    def test_not_a_linear_raises(self):
        with pytest.raises(ValueError):
            lplan.build_plan({"scale": jnp.ones((8,))})

    def test_plans_cached_per_geometry(self, rng):
        a, b = _lowrank(rng), _lowrank(jax.random.fold_in(rng, 1))
        assert lplan.build_plan(a) is lplan.build_plan(b)

    def test_builds_from_shape_structs(self):
        p = {"w0": jax.ShapeDtypeStruct((64, 8), jnp.float32),
             "w1": jax.ShapeDtypeStruct((8, 64), jnp.float32)}
        plan = lplan.build_plan(p)
        assert plan.kind == "lowrank" and plan.d_out == 64


class TestKernelEligibility:
    def test_decode_shaped_activations_are_eligible(self, rng):
        """Satellite: (B, 1, d) decode activations reach the kernels —
        the wrappers flatten leading dims, the plan no longer gates on
        x.ndim == 2."""
        plan = lplan.build_plan(_lowrank(rng))
        assert plan.kernel_for((4, 1, 128), True) == "lowrank"
        assert plan.kernel_for((2, 3, 128), True) == "lowrank"
        assert plan.kernel_for((16, 128), True) == "lowrank"
        assert plan.kernel_for((16, 128), False) is None

    def test_quantized_kernel_names(self, rng):
        assert lplan.build_plan(quantize_tree(_lowrank(rng))) \
            .kernel_for((8, 1, 128), True) == "lowrank_q"
        assert lplan.build_plan(quantize_tree(_branched(rng))) \
            .kernel_for((8, 1, 128), True) == "branched_q"

    def test_partial_quant_takes_reference_path(self, rng):
        plan = lplan.build_plan(quantize_tree(_lowrank(rng),
                                              targets=("w1",)))
        assert plan.kernel_for((16, 128), True) is None

    def test_stacked_factors_not_eligible(self):
        p = {"w0": jnp.zeros((4, 64, 8)), "w1": jnp.zeros((4, 8, 64))}
        assert lplan.build_plan(p).kernel_for((16, 64), True) is None

    def test_oversize_falls_back(self):
        p = {"w0": jnp.zeros((16384, 4096)), "w1": jnp.zeros((4096, 8192))}
        assert lplan.build_plan(p).kernel_for((1 << 20, 16384), True) is None

    def test_dense_and_conv_have_no_kernel(self, rng):
        assert lplan.build_plan({"w": jnp.zeros((64, 64))}) \
            .kernel_for((8, 64), True) is None


class TestExecution:
    @pytest.mark.parametrize("quant", [False, True])
    def test_lowrank_pallas_matches_reference_3d(self, quant, rng):
        p = _lowrank(rng)
        if quant:
            p = quantize_tree(p)
        x = jax.random.normal(jax.random.fold_in(rng, 7), (4, 1, 128)) * 0.1
        y_ref = apply_linear(p, x)
        y_pl = apply_linear(p, x, use_pallas=True)
        assert y_pl.shape == y_ref.shape == (4, 1, 64)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("quant", [False, True])
    def test_branched_pallas_matches_reference_3d(self, quant, rng):
        p = _branched(rng)
        if quant:
            p = quantize_tree(p)
        x = jax.random.normal(jax.random.fold_in(rng, 8), (4, 1, 128)) * 0.1
        y_ref = apply_linear(p, x)
        y_pl = apply_linear(p, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)

    def test_freeze_policy_stops_outer_factor_grads(self, rng):
        p = _lowrank(rng)
        x = jax.random.normal(jax.random.fold_in(rng, 9), (8, 128)) * 0.1

        def loss(p, freeze):
            return jnp.sum(apply_linear(p, x, freeze_factors=freeze) ** 2)

        g = jax.grad(loss)(p, True)
        assert float(jnp.abs(g["w0"]).max()) == 0.0     # frozen
        assert float(jnp.abs(g["w1"]).max()) > 0.0      # trainable

    def test_conv_kind_raises_in_apply_linear(self):
        tk = {"tucker_u": jnp.zeros((16, 4)), "core": jnp.zeros((3, 3, 4, 4)),
              "tucker_v": jnp.zeros((4, 16))}
        with pytest.raises(ValueError):
            apply_linear(tk, jnp.zeros((2, 16)))

    def test_quantized_tucker_conv_executes(self, rng):
        from repro.layers.conv import apply_conv, conv_out_channels
        ks = jax.random.split(rng, 3)
        p = {"tucker_u": jax.random.normal(ks[0], (16, 8)) * 0.1,
             "core": jax.random.normal(ks[1], (3, 3, 8, 8)) * 0.1,
             "tucker_v": jax.random.normal(ks[2], (8, 16)) * 0.1}
        x = jax.random.normal(jax.random.fold_in(rng, 3), (2, 8, 8, 16))
        y = apply_conv(p, x)
        yq = apply_conv(quantize_tree(p), x)
        assert conv_out_channels(quantize_tree(p)) == 16
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert rel <= 5e-2, rel


class TestAccounting:
    def test_param_count_excludes_scales(self, rng):
        """Satellite: *_scale leaves are not model parameters."""
        p = _lowrank(rng)
        pq = quantize_tree(p)
        want = sum(int(v.size) for v in p.values())
        assert linear_param_count(p) == want
        assert linear_param_count(pq) == want       # q values count, scales not
        assert linear_quant_bytes(p) == 0
        assert linear_quant_bytes(pq) > 0

    def test_flops_and_out_dim_invariant_under_quant(self, rng):
        for p in (_lowrank(rng), _branched(rng)):
            pq = quantize_tree(p)
            assert linear_out_dim(pq) == linear_out_dim(p)
            assert linear_flops(pq, 11) == linear_flops(p, 11)

    def test_weight_bytes_drop_under_quant(self, rng):
        p = _branched(rng)
        plain = lplan.build_plan(p)
        quant = lplan.build_plan(quantize_tree(p))
        assert quant.weight_bytes < plain.weight_bytes

    def test_tree_summary(self, rng):
        tree = {"a": {"up": _lowrank(rng)},
                "b": {"proj": quantize_tree(_branched(rng))},
                "norm": {"scale": jnp.ones((8,))}}
        plans = lplan.build_plan_tree(tree)
        s = lplan.tree_summary(plans)
        assert s["linears"] == 2 and s["quantized"] == 1
        assert s["by_kind"] == {"branched": 1, "lowrank": 1}
        assert s["quant_bytes"] > 0

    def test_plan_layer_time_quant_aware(self, rng):
        from repro.core.cost_model import plan_layer_time
        p = _lowrank(rng, c=2048, r=256, s=2048)
        t_bf16 = plan_layer_time(lplan.build_plan(p), 1)
        t_int8 = plan_layer_time(lplan.build_plan(quantize_tree(p)), 1)
        assert t_int8 < t_bf16        # decode (m=1) is weight-stream-bound

    def test_plan_layer_time_act_quant_mxu_rate(self, rng):
        """Satellite cross-check: at compute-bound prefill m, an int8
        plan with ``act_quantize`` runs at the int8 x int8 MXU rate —
        half the modelled time — while weight-only int8 (dequantized in
        VMEM, wide MXU operands) and bf16 plans are unchanged."""
        from repro.analysis.hw_specs import DEFAULT
        from repro.core.cost_model import plan_layer_time
        p = _lowrank(rng, c=2048, r=256, s=2048)
        qplan = lplan.build_plan(quantize_tree(p))
        m = 1 << 15                   # deep into the compute-bound regime
        t_wq = plan_layer_time(qplan, m)
        t_qa = plan_layer_time(qplan, m, act_quantize=True)
        assert t_qa == pytest.approx(t_wq / DEFAULT.int8_mxu_mult)
        # bf16 plan: flag is inert (dispatch mirror rejects it)
        fplan = lplan.build_plan(p)
        assert plan_layer_time(fplan, m, act_quantize=True) \
            == plan_layer_time(fplan, m)

    def test_plan_layer_time_act_quant_narrows_stream(self, rng):
        """Memory-bound side: under qa the activation stream is int8
        values + one f32 scale per row, so the modelled time drops when
        m is small enough to be stream-bound on activations."""
        from repro.core.cost_model import plan_layer_time
        p = _lowrank(rng, c=4096, r=64, s=4096)
        qplan = lplan.build_plan(quantize_tree(p))
        m = 4096                      # act stream rivals weight stream
        t_wq = plan_layer_time(qplan, m, act_bytes=4)
        t_qa = plan_layer_time(qplan, m, act_bytes=4, act_quantize=True)
        assert t_qa < t_wq

    def test_peak_flops_dtype_aware(self):
        from repro.analysis.hw_specs import DEFAULT
        assert DEFAULT.peak_flops(1) \
            == DEFAULT.peak_flops_bf16 * DEFAULT.int8_mxu_mult
        assert DEFAULT.peak_flops(2) == DEFAULT.peak_flops_bf16
        assert DEFAULT.peak_flops(4) == DEFAULT.peak_flops_bf16

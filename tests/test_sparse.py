"""2:4 factor sparsity: packing round trips, fused sparse-int8 kernel
parity, plan/dispatch contract, accounting, sharding, and end-to-end
compound-compressed serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant import (IDX_SUFFIX, SCALE_SUFFIX, SP_SUFFIX, quantize_array,
                         quantize_tree)
from repro.quant.sparse import (desparsify_tree, expand_sparse, is_sparse,
                                sparsify_array, sparsify_tree)


def _factors(key, c=32, r=16, s=48, scale=0.05):
    k0, k1 = jax.random.split(key)
    return (jax.random.normal(k0, (c, r)) * scale,
            jax.random.normal(k1, (r, s)) * scale)


# ---------------------------------------------------------------------------
# Packing round trips
# ---------------------------------------------------------------------------

class TestSparsifyArray:
    def test_shapes_and_dtypes(self, rng):
        w, _ = _factors(rng)
        sp, idx, scale = sparsify_array(w)
        assert sp.shape == (2, 8, 16) and sp.dtype == jnp.int8
        assert idx.shape == (2, 8, 1) and idx.dtype == jnp.int8
        assert scale.shape == (1, 16) and scale.dtype == jnp.float32

    def test_keeps_top2_by_row_l1(self, rng):
        w, _ = _factors(rng)
        dense = np.asarray(expand_sparse(*sparsify_array(w),
                                         dtype=jnp.float32))
        wn = np.asarray(w)
        score = np.abs(wn).sum(-1).reshape(-1, 4)      # (C/4, 4) L1 norms
        for g in range(score.shape[0]):
            kept = set(np.argsort(-score[g])[:2])
            for j in range(4):
                row = dense[4 * g + j]
                if j in kept:
                    # kept row round-trips within int8 quant error
                    assert np.abs(row - wn[4 * g + j]).max() < 2e-3
                else:
                    np.testing.assert_array_equal(row, 0.0)

    def test_mode_none_keeps_dtype_no_scale(self, rng):
        w = _factors(rng)[0].astype(jnp.bfloat16)
        sp, idx, scale = sparsify_array(w, mode="none")
        assert scale is None and sp.dtype == jnp.bfloat16
        dense = np.asarray(expand_sparse(sp, idx), np.float32)
        wn = np.asarray(w, np.float32)
        kept = np.abs(dense) > 0
        np.testing.assert_array_equal(dense[kept], wn[kept])

    def test_idx_ascending_within_group(self, rng):
        _, idx, _ = sparsify_array(_factors(rng)[0])
        i = np.asarray(idx)                            # (2, G, 1)
        assert (i >= 0).all() and (i <= 3).all()
        assert (i[0] < i[1]).all()

    def test_indivisible_input_dim_raises(self, rng):
        w = jax.random.normal(rng, (30, 8))
        with pytest.raises((ValueError, AssertionError)):
            sparsify_array(w)


class TestSparsifyTree:
    def test_key_rewrite_and_targets(self, rng):
        w0, w1 = _factors(rng)
        tree = {"ffn": {"w0": w0, "w1": w1}}
        sp = sparsify_tree(tree, mode="int8")
        node = sp["ffn"]
        assert set(node) == {"w0_sp", "w0_idx", "w0_scale",
                             "w1_sp", "w1_idx", "w1_scale"}
        assert is_sparse(node)
        only_w0 = sparsify_tree(tree, mode="int8", targets=("w0",))["ffn"]
        assert "w1" in only_w0 and "w0_sp" in only_w0

    def test_idempotent_and_quant_compose(self, rng):
        w0, w1 = _factors(rng)
        tree = {"w0": w0, "w1": w1, "xc": jnp.ones((8, 8))}
        sp = sparsify_tree(tree, mode="int8")          # xc not targeted
        again = sparsify_tree(sp, mode="int8")
        assert jax.tree.structure(sp) == jax.tree.structure(again)
        # quantize_tree after: picks up the plain xc, skips packed nodes
        q = quantize_tree(sp, mode="int8")
        assert "xc_q" in q and "w0_sp" in q and "w0_q" not in q

    def test_skips_indivisible_input_dim(self, rng):
        tree = {"w0": jax.random.normal(rng, (30, 8))}
        sp = sparsify_tree(tree, mode="int8")
        assert "w0" in sp and "w0_sp" not in sp

    def test_desparsify_round_trip(self, rng):
        w0, w1 = _factors(rng)
        sp = sparsify_tree({"w0": w0, "w1": w1}, mode="int8")
        dense = desparsify_tree(sp, dtype=jnp.float32)
        assert set(dense) == {"w0", "w1"}
        assert dense["w0"].shape == w0.shape
        # half the rows are exact zeros
        zeros = (np.asarray(dense["w0"]) == 0).all(-1).sum()
        assert zeros == w0.shape[0] // 2


# ---------------------------------------------------------------------------
# Fused kernel vs ref.py oracle (interpret mode) + fallback dispatch
# ---------------------------------------------------------------------------

def _sq_lowrank_args(rng, c=32, r=16, s=48, m=24, lead=()):
    w0, w1 = _factors(rng, c, r, s)
    x = (jax.random.normal(jax.random.fold_in(rng, 9), (*lead, m, c))
         * 0.1).astype(jnp.bfloat16)
    return (x, *sparsify_array(w0), *sparsify_array(w1))


def _sq_branched_args(rng, n=2, c=32, r1=8, r2=8, s=48, m=24):
    ks = jax.random.split(jax.random.fold_in(rng, 3), 4)
    u = jax.random.normal(ks[0], (n, c, r1)) * 0.05
    xc = jax.random.normal(ks[1], (n, r1, r2)) * 0.05
    v = jax.random.normal(ks[2], (n, r2, s)) * 0.05
    x = (jax.random.normal(ks[3], (m, c)) * 0.1).astype(jnp.bfloat16)
    return (x, *sparsify_array(u), *quantize_array(xc), *sparsify_array(v))


class TestSparseKernels:
    TOL = 1e-2                        # the acceptance bound; observed 0

    @pytest.mark.parametrize("m,lead", [(24, ()), (1, (3,)), (8, (2, 2))])
    def test_lowrank_sq_matches_oracle(self, rng, m, lead):
        args = _sq_lowrank_args(rng, m=m, lead=lead)
        got = ops.lowrank_matmul_sq(*args, force_kernel=True)
        want = ref.lowrank_matmul_sq_ref(*args)
        assert got.shape == want.shape
        assert float(jnp.abs(got.astype(jnp.float32)
                             - want.astype(jnp.float32)).max()) <= self.TOL

    def test_lowrank_sq_padding_path(self, rng):
        # S=40 < DEFAULT_BN and M=5 not a multiple of any block: both
        # pads trigger inside the wrapper.
        args = _sq_lowrank_args(rng, c=32, r=16, s=40, m=5)
        got = ops.lowrank_matmul_sq(*args, force_kernel=True)
        want = ref.lowrank_matmul_sq_ref(*args)
        assert float(jnp.abs(got.astype(jnp.float32)
                             - want.astype(jnp.float32)).max()) <= self.TOL

    @pytest.mark.parametrize("m", [24, 1])
    def test_branched_sq_matches_oracle(self, rng, m):
        args = _sq_branched_args(rng, m=m)
        got = ops.branched_matmul_sq(*args, force_kernel=True)
        want = ref.branched_matmul_sq_ref(*args)
        assert got.shape == want.shape
        assert float(jnp.abs(got.astype(jnp.float32)
                             - want.astype(jnp.float32)).max()) <= self.TOL

    def test_kernel_fits_rejection_falls_back_bit_exact(self, rng,
                                                        monkeypatch):
        """VMEM gate closed -> the ops wrappers dispatch the unfused
        reference path, bit-identical to calling it directly."""
        monkeypatch.setattr(ops, "VMEM_BUDGET", 0)
        lr = _sq_lowrank_args(rng)
        assert not ops.kernel_fits("lowrank_sq", 24, c=32, r=16, s=48)
        np.testing.assert_array_equal(
            np.asarray(ops.lowrank_matmul_sq(*lr)),
            np.asarray(ref.lowrank_matmul_sq_ref(*lr)))
        br = _sq_branched_args(rng)
        assert not ops.kernel_fits("branched_sq", 24, c=32, r1=8, r2=8,
                                   s=48)
        np.testing.assert_array_equal(
            np.asarray(ops.branched_matmul_sq(*br)),
            np.asarray(ref.branched_matmul_sq_ref(*br)))

    def test_plan_execute_respects_closed_gate(self, rng, monkeypatch):
        """kernel_for returns None under a closed gate and execute still
        produces the reference result (dense-fallback dispatch)."""
        from repro.layers import plan as lplan
        w0, w1 = _factors(rng)
        tree = sparsify_tree({"w0": w0, "w1": w1}, mode="int8")
        x = (jax.random.normal(rng, (8, 32)) * 0.1).astype(jnp.bfloat16)
        p = lplan.build_plan(tree)
        open_y = p.execute(tree, x, use_pallas=True)
        monkeypatch.setattr(ops, "VMEM_BUDGET", 0)
        assert p.kernel_for(x.shape, True) is None
        closed_y = p.execute(tree, x, use_pallas=True)
        ref_y = p.execute(tree, x, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(closed_y),
                                      np.asarray(ref_y))
        assert float(jnp.abs(open_y.astype(jnp.float32)
                             - ref_y.astype(jnp.float32)).max()) <= self.TOL


# ---------------------------------------------------------------------------
# Plan contract + accounting
# ---------------------------------------------------------------------------

class TestSparsePlan:
    def _lowrank_tree(self, rng, mode="int8", c=32, r=16, s=48):
        w0, w1 = _factors(rng, c, r, s)
        return sparsify_tree({"w0": w0, "w1": w1}, mode=mode)

    def test_classification_and_spec(self, rng):
        from repro.layers import plan as lplan
        tree = self._lowrank_tree(rng)
        p = lplan.build_plan(tree)
        assert p.kind == lplan.KIND_LOWRANK and p.sparse and p.quantized
        f = p.factor("w0")
        assert f.sparsity == "2:4" and f.shape == (32, 16)
        assert f.density == 0.5 and f.idx_shape == (2, 8, 1)
        assert p.d_in == 32 and p.d_out == 48

    def test_kernel_names(self, rng):
        from repro.layers import plan as lplan
        p = lplan.build_plan(self._lowrank_tree(rng))
        assert p.kernel_for((8, 32), True) == "lowrank_sq"
        assert p.kernel_for((8, 32), False) is None

        btree = sparsify_tree(
            {"u": jax.random.normal(rng, (2, 32, 8)) * 0.05,
             "xc": jax.random.normal(rng, (2, 8, 8)) * 0.05,
             "v": jax.random.normal(rng, (2, 8, 48)) * 0.05},
            mode="int8")
        btree = quantize_tree(btree, mode="int8")      # xc -> int8
        bp = lplan.build_plan(btree)
        assert bp.kernel_for((8, 32), True) == "branched_sq"

    def test_mixed_and_unquantized_sparse_take_reference(self, rng):
        from repro.layers import plan as lplan
        # bf16-sparse (mode="none"): no fused kernel serves it
        p_none = lplan.build_plan(self._lowrank_tree(rng, mode="none"))
        assert p_none.kernel_for((8, 32), True) is None
        # partial sparse_targets: w0 packed, w1 plain
        w0, w1 = _factors(rng)
        mixed = sparsify_tree({"w0": w0, "w1": w1}, mode="int8",
                              targets=("w0",))
        p_mixed = lplan.build_plan(mixed)
        assert p_mixed.kernel_for((8, 32), True) is None
        # both still execute (reference expand path)
        x = (jax.random.normal(rng, (4, 32)) * 0.1).astype(jnp.bfloat16)
        assert p_mixed.execute(mixed, x, use_pallas=True).shape == (4, 48)

    def test_param_count_excludes_idx_and_scale(self, rng):
        from repro.layers import plan as lplan
        tree = self._lowrank_tree(rng)
        p = lplan.build_plan(tree)
        # packed values only: half the logical counts
        assert p.param_count == (32 * 16 + 16 * 48) // 2
        # the tree-walk twin (benchmarks.common.param_count semantics):
        # *_idx and *_scale leaves are metadata, *_sp values count
        walked = sum(
            int(leaf.size)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
            if not str(getattr(path[-1], "key", path[-1])).endswith(
                (SCALE_SUFFIX, IDX_SUFFIX)))
        assert walked == p.param_count
        # and the suffix constants really partition the tree's keys
        keys = {str(getattr(pth[-1], "key", pth[-1]))
                for pth, _ in jax.tree_util.tree_flatten_with_path(tree)[0]}
        assert {k for k in keys if k.endswith(SP_SUFFIX)} \
            == {"w0" + SP_SUFFIX, "w1" + SP_SUFFIX}

    def test_weight_bytes_formula(self, rng):
        from repro.layers import plan as lplan
        c, r, s = 32, 16, 48
        p = lplan.build_plan(self._lowrank_tree(rng, c=c, r=r, s=s))
        packed = (c * r + r * s) // 2                  # int8 kept values
        idx = c // 2 + r // 2                          # one int8 per group
        scales = 4 * (r + s)                           # f32 rows
        assert p.weight_bytes == packed + idx + scales
        assert p.quant_bytes == p.weight_bytes

    def test_chain_density_and_cost_model(self, rng):
        from repro.core import cost_model as cm
        from repro.layers import plan as lplan
        w0, w1 = _factors(rng)
        sq = lplan.build_plan(self._lowrank_tree(rng))
        q = lplan.build_plan(quantize_tree({"w0": w0, "w1": w1},
                                           mode="int8"))
        assert sq.chain_density() == (0.5, 0.5)
        assert q.chain_density() == (1.0, 1.0)
        assert sq.flops_per_token == q.flops_per_token / 2
        # memory-bound decode: fewer weight bytes -> strictly faster
        assert cm.plan_layer_time(sq, 1) < cm.plan_layer_time(q, 1)

    def test_tree_summary_counts_sparse(self, rng):
        from repro.layers import plan as lplan
        tree = {"a": self._lowrank_tree(rng),
                "b": {"w0": _factors(rng)[0], "w1": _factors(rng)[1]}}
        summary = lplan.tree_summary(lplan.build_plan_tree(tree))
        assert summary["linears"] == 2 and summary["sparse"] == 1


# ---------------------------------------------------------------------------
# apply_linear + sharding + engine end to end
# ---------------------------------------------------------------------------

class TestSparseEndToEnd:
    def test_apply_linear_matches_desparsified_dense(self, rng):
        from repro.layers.param import apply_linear
        w0, w1 = _factors(rng)
        sp = sparsify_tree({"w0": w0, "w1": w1}, mode="int8")
        dense = desparsify_tree(sp, dtype=jnp.float32)
        x = (jax.random.normal(rng, (6, 32)) * 0.1).astype(jnp.bfloat16)
        y_dense = apply_linear({k: v.astype(jnp.bfloat16)
                                for k, v in dense.items()}, x)
        for use_pallas in (False, True):
            y_sp = apply_linear(sp, x, use_pallas=use_pallas)
            assert float(jnp.abs(y_sp.astype(jnp.float32)
                                 - y_dense.astype(jnp.float32)).max()) < 1e-2

    def test_align_quantized_axes_covers_sparse_leaves(self, rng):
        from repro.quant import align_quantized_axes
        w0, w1 = _factors(rng)
        axes = {"w0": ("embed", "rank"), "w1": ("rank", "ffn")}
        sp, sp_axes = sparsify_tree({"w0": w0, "w1": w1}, mode="int8",
                                    axes=axes)
        aligned = align_quantized_axes(sp, axes)
        assert set(aligned) == set(sp)
        assert aligned == sp_axes
        # packed values: out-dim axis survives, packed axes replicate
        assert aligned["w0_sp"] == (None, "embed", "rank")
        assert aligned["w0_idx"] == (None, "embed", None)
        assert aligned["w0_scale"] == (None, "rank")

    def test_engine_compound_compression(self, rng):
        from repro.configs import registry
        from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
        from repro.core.surgery import decompose_model, sparsify_model
        from repro.models.api import get_model
        from repro.serve.engine import Request, ServeEngine

        cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                                  dtype="float32")
        lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="aligned",
                        rank_align=8, min_dim=32)
        run = RunConfig(model=cfg, parallel=ParallelConfig(), lrd=lrd)
        m = get_model(cfg)
        params, axes = m.init(jax.random.PRNGKey(0))
        params, axes, _ = decompose_model(params, axes, run.lrd)

        # surgery-level pass rewrites params AND axes coherently
        lrd_sp = dataclasses.replace(lrd, sparsify="2:4", quantize="int8")
        p2, a2 = sparsify_model(params, axes, lrd_sp)
        flat_p = jax.tree_util.tree_flatten_with_path(p2)[0]
        sp_keys = {str(getattr(pth[-1], "key", pth[-1]))
                   for pth, _ in flat_p}
        assert any(k.endswith(SP_SUFFIX) for k in sp_keys)
        assert jax.tree.structure(p2) == jax.tree.structure(
            a2, is_leaf=lambda n: isinstance(n, tuple))

        def serve(eng):
            reqs = [Request(uid=i, prompt=[i + 1, 2, 3], max_new_tokens=4)
                    for i in range(3)]
            for r in reqs:
                eng.add_request(r)
            eng.run_until_done()
            assert all(r.done and len(r.output) == 4 for r in reqs)
            return [r.output for r in reqs]

        eng_q = ServeEngine(run, params, slots=2, max_seq=64,
                            quantize="int8")
        eng_sq = ServeEngine(run, params, slots=2, max_seq=64,
                             quantize="int8", sparsify="2:4")
        assert eng_sq.sparsify == "2:4"
        assert eng_sq.plan_summary["sparse"] > 0
        assert (eng_sq.plan_summary["weight_bytes"]
                < eng_q.plan_summary["weight_bytes"])
        serve(eng_q)
        out_sq = serve(eng_sq)
        # the sq engine serves exactly what its expanded-dense twin would
        dense_tw = desparsify_tree(
            ServeEngine(run, params, slots=2, max_seq=64,
                        sparsify="2:4").params, dtype=jnp.float32)
        out_dense = serve(ServeEngine(run, dense_tw, slots=2, max_seq=64,
                                      quantize="int8"))
        assert len(out_sq) == len(out_dense) == 3

    def test_config_knob_drives_engine(self, rng):
        from repro.configs import registry
        from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
        from repro.core.surgery import decompose_model
        from repro.models.api import get_model
        from repro.serve.engine import ServeEngine

        cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                                  dtype="float32")
        lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="aligned",
                        rank_align=8, min_dim=32, sparsify="2:4",
                        quantize="int8")
        run = RunConfig(model=cfg, parallel=ParallelConfig(), lrd=lrd)
        m = get_model(cfg)
        params, axes = m.init(jax.random.PRNGKey(0))
        params, _, _ = decompose_model(params, axes, lrd)
        eng = ServeEngine(run, params, slots=2, max_seq=64)
        assert eng.sparsify == "2:4" and eng.quantize == "int8"
        assert eng.plan_summary["sparse"] > 0

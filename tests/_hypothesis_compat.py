"""Optional-hypothesis shim: property tests skip when hypothesis is absent.

The container does not ship ``hypothesis`` (see requirements-test.txt for
the pinned dev environment).  Test modules import ``given``/``settings``/
``st`` from here instead of from hypothesis directly; without the package
the ``@given`` tests collect as skips and the plain unit tests still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the strategies are never drawn from
        because @given skips the test)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

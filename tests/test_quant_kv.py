"""Runtime KV quantization: round-trip bounds, fused decode kernel
parity, prefill bucketing, and end-to-end int8-KV serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.layers import attention as attn
from repro.quant import kv as kvq
from repro.quant.quantize import INT8_QMAX


def _rand_kv(key, b, s, kh, d, scale=1.0):
    return jax.random.normal(key, (b, s, kh, d), jnp.float32) * scale


# ---------------------------------------------------------------------------
# Quantize / dequantize round trips
# ---------------------------------------------------------------------------

class TestKVRoundTrip:
    def test_prefill_round_trip_error_bound(self, rng):
        x = _rand_kv(rng, 2, 32, 4, 64)
        q, scale = kvq.quantize_kv_prefill(x)
        back = kvq.dequantize_kv(q, scale)
        # symmetric int8: per-channel max abs error <= scale / 2
        bound = jnp.broadcast_to(scale[:, None] / 2 + 1e-8, x.shape)
        assert bool(jnp.all(jnp.abs(back - x) <= bound))
        rel = float(jnp.linalg.norm(back - x) / jnp.linalg.norm(x))
        assert rel < 1e-2

    def test_incremental_write_matches_one_shot(self, rng):
        """Decode-style token-by-token writes stay within one extra LSB
        of the one-shot prompt quantization."""
        b, s, kh, d = 2, 24, 2, 32
        x = _rand_kv(rng, b, s, kh, d)
        cache = jnp.zeros((b, s, kh, d), jnp.int8)
        scale = jnp.zeros((b, kh, d), jnp.float32)
        for t in range(s):
            cache, scale = kvq.kv_write_token(
                cache, scale, x[:, t], jnp.full((b,), t, jnp.int32))
        back = kvq.dequantize_kv(cache, scale)
        # running-max scale equals the one-shot scale after all writes
        _, scale_ref = kvq.quantize_kv_prefill(x)
        np.testing.assert_allclose(np.asarray(scale), np.asarray(scale_ref),
                                   rtol=1e-6)
        # rescale-in-place costs at most ~1 LSB on top of the half-LSB
        bound = jnp.broadcast_to(1.5 * scale[:, None] + 1e-8, x.shape)
        assert bool(jnp.all(jnp.abs(back - x) <= bound))

    def test_write_token_noop_when_scale_unchanged(self, rng):
        """A new token under the running max must not perturb history."""
        b, s, kh, d = 1, 8, 2, 16
        x = _rand_kv(rng, b, s, kh, d)
        cache, scale = kvq.quantize_kv_prefill(x)
        small = x[:, 0] * 1e-3          # well inside the existing scale
        cache2, scale2 = kvq.kv_write_token(cache, scale, small,
                                            jnp.full((b,), s - 1, jnp.int32))
        np.testing.assert_array_equal(np.asarray(scale2), np.asarray(scale))
        np.testing.assert_array_equal(np.asarray(cache2[:, :-1]),
                                      np.asarray(cache[:, :-1]))

    def test_zero_cache_dequantizes_to_zero(self):
        c = kvq.init_kv_cache_q(2, 16, 2, 8)
        assert c["k_q"].dtype == jnp.int8
        back = kvq.dequantize_kv(c["k_q"], c["k_scale"])
        assert float(jnp.abs(back).max()) == 0.0

    def test_values_clip_to_qmax(self, rng):
        x = _rand_kv(rng, 1, 4, 1, 8, scale=100.0)
        q, _ = kvq.quantize_kv_prefill(x)
        assert int(jnp.abs(q.astype(jnp.int32)).max()) <= INT8_QMAX

    def test_bytes_per_step_ratio(self):
        f32 = kvq.kv_bytes_per_step(4, 64, 2, 64)
        int8 = kvq.kv_bytes_per_step(4, 64, 2, 64, quantize="int8")
        assert f32 / int8 >= 3.5
        # int8 = 1 byte/elt + the f32 scale rows
        n = 4 * 64 * 2 * 64
        assert int8 == 2 * n + 2 * 4 * 2 * 64 * 4

    def test_unknown_mode_raises(self):
        with pytest.raises(ValueError):
            kvq.kv_cache_spec_q(1, 8, 1, 8, mode="int4")


# ---------------------------------------------------------------------------
# Fused decode-attention kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------

DECODE_SHAPES = [
    # b, s, h, kh, d, bs
    (2, 64, 8, 2, 64, 32),        # GQA group of 4
    (3, 100, 4, 4, 128, 64),      # MHA, unaligned S -> padding path
    (1, 16, 8, 1, 64, 128),       # MQA, S smaller than one block
    (4, 256, 4, 2, 64, 128),      # multi-block online softmax
]


class TestDecodeAttentionQKernel:
    @pytest.mark.parametrize("b,s,h,kh,d,bs", DECODE_SHAPES)
    def test_kernel_matches_ref(self, b, s, h, kh, d, bs, rng):
        ks = jax.random.split(jax.random.fold_in(rng, b * s), 4)
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32) * 0.5
        k_q, k_s = kvq.quantize_kv_prefill(_rand_kv(ks[1], b, s, kh, d))
        v_q, v_s = kvq.quantize_kv_prefill(_rand_kv(ks[2], b, s, kh, d))
        cache_pos = jax.random.randint(ks[3], (b,), 1, s - 1)
        got = ops.decode_attention_q(q, k_q, k_s, v_q, v_s, cache_pos,
                                     bs=bs, force_kernel=True)
        want = ref.decode_attention_q_ref(q, k_q, k_s, v_q, v_s, cache_pos)
        assert got.shape == want.shape == (b, 1, h, d)
        assert float(jnp.abs(got - want).max()) <= 1e-2

    def test_kernel_matches_ref_softcap(self, rng):
        b, s, h, kh, d = 2, 64, 4, 2, 64
        ks = jax.random.split(rng, 4)
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
        k_q, k_s = kvq.quantize_kv_prefill(_rand_kv(ks[1], b, s, kh, d))
        v_q, v_s = kvq.quantize_kv_prefill(_rand_kv(ks[2], b, s, kh, d))
        cache_pos = jnp.asarray([s - 1, 7])
        got = ops.decode_attention_q(q, k_q, k_s, v_q, v_s, cache_pos,
                                     softcap=30.0, force_kernel=True)
        want = ref.decode_attention_q_ref(q, k_q, k_s, v_q, v_s, cache_pos,
                                          softcap=30.0)
        assert float(jnp.abs(got - want).max()) <= 1e-2

    def test_ref_matches_f32_attention_on_dequantized_pool(self, rng):
        """The oracle itself == the engine's f32 decode attention run on
        the dequantized pool (same masking semantics)."""
        b, s, h, kh, d = 2, 32, 4, 2, 16
        ks = jax.random.split(rng, 4)
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
        k_q, k_s = kvq.quantize_kv_prefill(_rand_kv(ks[1], b, s, kh, d))
        v_q, v_s = kvq.quantize_kv_prefill(_rand_kv(ks[2], b, s, kh, d))
        cache_pos = jnp.asarray([5, s - 1])
        got = ref.decode_attention_q_ref(q, k_q, k_s, v_q, v_s, cache_pos)
        kd, vd = kvq.dequantize_kv(k_q, k_s), kvq.dequantize_kv(v_q, v_s)
        valid = jnp.arange(s)[None, :] <= cache_pos[:, None]
        want = attn._decode_attention(q, kd, vd, valid, 0.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

    def test_padding_positions_do_not_leak(self, rng):
        """S not a bs multiple: the wrapper pads, the validity mask must
        neutralize the padded tail."""
        b, s, h, kh, d = 1, 48, 2, 2, 64
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
        k_q, k_s = kvq.quantize_kv_prefill(_rand_kv(ks[1], b, s, kh, d))
        v_q, v_s = kvq.quantize_kv_prefill(_rand_kv(ks[2], b, s, kh, d))
        pos = jnp.asarray([s - 1])
        got = ops.decode_attention_q(q, k_q, k_s, v_q, v_s, pos,
                                     bs=32, force_kernel=True)
        want = ref.decode_attention_q_ref(q, k_q, k_s, v_q, v_s, pos)
        assert float(jnp.abs(got - want).max()) <= 1e-2

    def test_vmem_fallback_dispatch(self):
        assert ops.kernel_fits("decode_attn_q", 4, c=64, s=128, r=4)
        # an absurd GQA group * head_dim blows the budget -> ref path
        assert not ops.kernel_fits("decode_attn_q", 4, c=4096, s=128,
                                   r=4096, bn=4096)


# ---------------------------------------------------------------------------
# Attention-layer integration (quantized cache dict drives the branch)
# ---------------------------------------------------------------------------

class TestAttentionKVQuantized:
    def test_cache_spec_variants(self):
        spec = attn.kv_cache_spec(2, 16, 2, 8, jnp.float32, "int8")
        assert set(spec) == {"k_q", "k_scale", "v_q", "v_scale"}
        assert spec["k_q"].dtype == jnp.int8
        plain = attn.kv_cache_spec(2, 16, 2, 8, jnp.float32)
        assert set(plain) == {"k", "v"}
        init = attn.init_kv_cache(2, 16, 2, 8, jnp.float32, "int8")
        assert kvq.is_quantized_kv(init)
        assert not kvq.is_quantized_kv(attn.init_kv_cache(
            2, 16, 2, 8, jnp.float32))

    def test_prefill_then_decode_close_to_f32(self, rng):
        """One attention layer, prefill + 3 decode steps, int8 cache vs
        f32 cache: outputs agree to quantization error."""
        from repro.layers.param import ParamBuilder
        d_model, h, kh, hd = 32, 4, 2, 8
        pb = ParamBuilder(rng, jnp.float32)
        attn.init_attention(pb, "a", d_model, h, kh, hd)
        p = pb.params["a"]
        b, s_prompt, s_max = 2, 5, 16
        x = jax.random.normal(jax.random.fold_in(rng, 1),
                              (b, s_prompt, d_model), jnp.float32) * 0.3
        pos = jnp.broadcast_to(jnp.arange(s_prompt)[None], (b, s_prompt))
        kw = dict(num_heads=h, num_kv_heads=kh, head_dim=hd,
                  rope_theta=1e4, positions=pos)
        caches = {}
        for mode in (None, "int8"):
            cache = attn.init_kv_cache(b, s_max, kh, hd, jnp.float32, mode)
            o, cache = attn.apply_attention(p, x, cache=cache, **kw)
            outs = [o]
            for t in range(3):
                cp = jnp.full((b,), s_prompt + t, jnp.int32)
                xt = jax.random.normal(jax.random.fold_in(rng, 10 + t),
                                       (b, 1, d_model), jnp.float32) * 0.3
                o, cache = attn.apply_attention(
                    p, xt, cache=cache, cache_pos=cp,
                    **{**kw, "positions": cp[:, None]})
                outs.append(o)
            caches[mode] = (outs, cache)
        for of, oq in zip(*[caches[m][0] for m in (None, "int8")]):
            assert float(jnp.abs(of - oq).max()) < 5e-2
        assert caches["int8"][1]["k_q"].dtype == jnp.int8


# ---------------------------------------------------------------------------
# End-to-end serving + admission bucketing
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import registry
    from repro.configs.base import ParallelConfig, RunConfig
    from repro.models.api import get_model

    # f32 model dtype: the comparison isolates KV quantization error
    # (bf16 rounding would otherwise flip near-tied greedy argmaxes).
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _run_engine(run, params, *, kv_quantize=None, lrd=None, slots=2,
                prompts=((1, 2, 3), (4, 5, 6, 7), (2,)), n=6):
    from repro.serve.engine import Request, ServeEngine
    run2 = run if lrd is None else dataclasses.replace(run, lrd=lrd)
    eng = ServeEngine(run2, params, slots=slots, max_seq=64,
                      kv_quantize=kv_quantize)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [r.output for r in reqs]


class TestServeKVQuantized:
    def test_int8_kv_greedy_matches_f32(self, serve_setup):
        run, m, params = serve_setup
        _, out_f = _run_engine(run, params)
        eng, out_q = _run_engine(run, params, kv_quantize="int8")
        assert out_f == out_q
        # the pool stayed int8 after prefill inserts + decode scatters
        leaves = jax.tree_util.tree_flatten_with_path(eng.cache)[0]
        dtypes = {str(getattr(p[-1], "key", p[-1])): l.dtype
                  for p, l in leaves}
        assert dtypes["k_q"] == jnp.int8 and dtypes["v_q"] == jnp.int8
        assert dtypes["k_scale"] == jnp.float32

    def test_int8_kv_with_pallas_kernel(self, serve_setup):
        """lrd.use_pallas routes decode through the fused kernel
        (interpret mode on CPU) — outputs match the jnp oracle path."""
        from repro.configs.base import LRDConfig
        run, m, params = serve_setup
        _, out_ref = _run_engine(run, params, kv_quantize="int8",
                                 prompts=((1, 2, 3),), n=3)
        _, out_k = _run_engine(run, params, kv_quantize="int8",
                               lrd=LRDConfig(use_pallas=True),
                               prompts=((1, 2, 3),), n=3)
        assert out_ref == out_k

    def test_kv_bytes_accounting(self, serve_setup):
        run, m, params = serve_setup
        eng_f, _ = _run_engine(run, params)
        eng_q, _ = _run_engine(run, params, kv_quantize="int8")
        bf = eng_f.plan_summary["kv_bytes_per_step"]
        bq = eng_q.plan_summary["kv_bytes_per_step"]
        assert bf / bq >= 3.5

    def test_config_knob_drives_engine(self, serve_setup):
        from repro.configs.base import LRDConfig
        run, m, params = serve_setup
        lrd = dataclasses.replace(LRDConfig(), kv_quantize="int8")
        eng, out = _run_engine(run, params, lrd=lrd, prompts=((1, 2, 3),),
                               n=2)
        assert eng.kv_quantize == "int8"
        assert kvq.is_quantized_kv(
            jax.tree.leaves(eng.cache, is_leaf=kvq.is_quantized_kv)[0])


class TestPrefillBucketing:
    def test_bucket_lengths(self, serve_setup):
        from repro.serve.engine import ServeEngine
        run, m, params = serve_setup
        eng = ServeEngine(run, params, slots=1, max_seq=64)
        assert eng._bucket_len(1) == 8 and eng._bucket_len(8) == 8
        assert eng._bucket_len(9) == 16 and eng._bucket_len(33) == 64
        assert eng._bucket_len(60) == 64    # capped at max_seq

    def test_no_retrace_within_bucket(self, serve_setup):
        run, m, params = serve_setup
        eng, _ = _run_engine(run, params,
                             prompts=((1, 2, 3), (4, 5, 6, 7), (2, 3)), n=2)
        # lengths 3, 4, 2 all land in the 8-bucket: ONE compiled prefill
        assert eng._jit_prefill._cache_size() == 1
        # admit rounds of varying size pad to (slots, V): the sampler
        # shares the decode path's single compiled shape
        assert eng._jit_sample_all._cache_size() == 1

    def test_padded_tail_masked_in_pool(self, serve_setup):
        """Bucket padding beyond the prompt must land as zeros in the
        inserted slot (int8 pool: dequantizes to exact zero)."""
        run, m, params = serve_setup
        eng, _ = _run_engine(run, params, kv_quantize="int8",
                             prompts=((1, 2, 3),), n=1, slots=1)
        k_q = eng.cache["blocks"]["k_q"]          # (L, slots, S, KH, D)
        n_written = 3 + 1                         # prompt + 1 decode step
        tail = k_q[:, :, n_written:]
        assert int(jnp.abs(tail.astype(jnp.int32)).max()) == 0

    def test_recurrent_family_not_bucketed(self):
        """SSM state advances through pad tokens, so ssm/hybrid prompts
        must prefill unpadded — and still serve correctly."""
        from repro.configs import registry
        from repro.configs.base import ParallelConfig, RunConfig
        from repro.models.api import get_model
        from repro.serve.engine import Request, ServeEngine
        cfg = registry.get("mamba2-2.7b").smoke
        run = RunConfig(model=cfg, parallel=ParallelConfig())
        m = get_model(cfg)
        params, _ = m.init(jax.random.PRNGKey(0))
        eng = ServeEngine(run, params, slots=1, max_seq=32)
        assert eng._bucket_len(3) == 3 and eng._bucket_len(9) == 9
        # pure-SSM model: recurrent state is not a KV stream
        assert eng.plan_summary["kv_bytes_per_step"] == 0
        prompt = [5, 9, 2]
        req = Request(uid=0, prompt=prompt, max_new_tokens=4)
        eng.add_request(req)
        eng.run_until_done()
        toks = list(prompt)
        for _ in range(4):
            x, _ = m.forward(params, {"tokens": jnp.asarray([toks])})
            logits = m.logits(params, x)
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert req.output == toks[len(prompt):]

    def test_prompt_len_masks_quantization_scales(self, rng):
        """Padded prefill with prompt_len produces the same int8 cache
        (values AND scales) as the unpadded prompt — bucket padding
        cannot inflate the per-channel scales."""
        from repro.layers.param import ParamBuilder
        d_model, h, kh, hd = 32, 4, 2, 8
        pb = ParamBuilder(rng, jnp.float32)
        attn.init_attention(pb, "a", d_model, h, kh, hd)
        p = pb.params["a"]
        n, bucket, s_max = 3, 8, 16
        x = jax.random.normal(jax.random.fold_in(rng, 2),
                              (1, bucket, d_model), jnp.float32)
        def prefill(xx, plen):
            s = xx.shape[1]
            pos = jnp.arange(s)[None, :]
            cache = attn.init_kv_cache(1, s_max, kh, hd, jnp.float32, "int8")
            _, c = attn.apply_attention(
                p, xx, num_heads=h, num_kv_heads=kh, head_dim=hd,
                rope_theta=1e4, positions=pos, cache=cache,
                prompt_len=plen)
            return c
        padded = prefill(x, jnp.asarray(n))
        exact = prefill(x[:, :n], None)
        np.testing.assert_array_equal(np.asarray(padded["k_scale"]),
                                      np.asarray(exact["k_scale"]))
        np.testing.assert_array_equal(np.asarray(padded["k_q"][:, :n]),
                                      np.asarray(exact["k_q"][:, :n]))
        assert int(jnp.abs(
            padded["k_q"][:, n:].astype(jnp.int32)).max()) == 0

    def test_bucketed_outputs_match_unpadded_reference(self, serve_setup):
        """Greedy outputs equal the repeated-full-forward reference even
        though the prompt was padded to a bucket."""
        run, m, params = serve_setup
        prompt = [5, 9, 2]                        # length 3 -> bucket 8
        _, outs = _run_engine(run, params, prompts=(tuple(prompt),), n=5)
        toks = list(prompt)
        for _ in range(5):
            x, _ = m.forward(params, {"tokens": jnp.asarray([toks])})
            logits = m.logits(params, x)
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert outs[0] == toks[len(prompt):]


class TestTokenMatchRegression:
    """Fixed-seed pin of the int8-KV greedy agreement the serve bench
    records (BENCH_serve.json, slots=2/s_max=64: 0.9688 — i.e. 31 of
    32 tokens).  A silent drop here means a KV-quant accuracy
    regression that the allclose tests are too loose to catch."""

    PINNED = 31 / 32                  # the bench's 0.9688, unrounded

    def test_int8_kv_decode_token_match_pinned(self, serve_setup):
        # Exact replica of the bench's (2, 64) sweep point: 4 requests
        # whose prompt lengths straddle two power-of-2 buckets.
        run, m, params = serve_setup
        prompts = tuple(tuple([(i % 7) + 1] * (3 + (i % 8)))
                        for i in range(4))
        _, out_f = _run_engine(run, params, prompts=prompts, n=8)
        _, out_q = _run_engine(run, params, kv_quantize="int8",
                               prompts=prompts, n=8)
        flat_f = [t for o in out_f for t in o]
        flat_q = [t for o in out_q for t in o]
        assert len(flat_f) == len(flat_q) == 32
        match = sum(a == b for a, b in zip(flat_f, flat_q)) / len(flat_f)
        assert match >= self.PINNED - 1e-9, (
            f"int8-KV token_match regressed: {match:.4f} < {self.PINNED}")

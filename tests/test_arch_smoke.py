"""Per-architecture smoke tests (deliverable f): reduced config of each
family runs one forward/train step on CPU — output shapes + no NaNs —
plus prefill/decode consistency and LRD surgery round-trips."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import LRDConfig, RunConfig, ShapeConfig
from repro.core.surgery import decompose_model
from repro.models.api import get_model, synth_inputs
from repro.train import steps as steps_mod
from repro.train.optim import OptimConfig

SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
ASSIGNED = registry.assigned_names()


@pytest.mark.parametrize("arch", ASSIGNED + ["resnet50"])
def test_forward_loss_no_nan(arch):
    cfg = registry.get(arch).smoke
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    batch = synth_inputs(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    loss, metrics = m.loss(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_improves_loss(arch):
    cfg = registry.get(arch).smoke
    entry = registry.get(arch)
    run = RunConfig(model=cfg,
                    parallel=dataclasses.replace(entry.parallel("train"),
                                                 seq_shard=False,
                                                 fsdp=False, remat="none"))
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    opt_cfg = OptimConfig(peak_lr=3e-3, warmup_steps=1, total_steps=6)
    opt = steps_mod.init_opt_state(m, run, params, opt_cfg)
    step = jax.jit(steps_mod.make_train_step(m, run, opt_cfg))
    batch = synth_inputs(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    losses = []
    for _ in range(4):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert not jnp.isnan(metrics["loss"])
    assert losses[-1] < losses[0]     # memorizes the repeated batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_lrd_surgery_runs_and_shrinks(arch):
    """The paper's technique applies to every assigned arch (or records a
    principled skip) and the decomposed model still runs."""
    cfg = registry.get(arch).smoke
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="ratio",
                    min_dim=32)
    p2, a2, report = decompose_model(params, axes, lrd)
    assert report.params_after <= report.params_before
    assert len(report.decomposed) > 0, "no layer decomposed"
    batch = synth_inputs(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    loss, _ = m.loss(p2, batch)
    assert not bool(jnp.isnan(loss))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if registry.get(a).smoke.has_decode])
def test_prefill_decode_matches_forward(arch):
    cfg = registry.get(arch).smoke
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 3), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.family == "vlm":
        batch["image_embeds"] = (jax.random.normal(
            jax.random.PRNGKey(4),
            (B, cfg.num_image_tokens, cfg.d_model), jnp.float32) * 0.2
        ).astype(m.dtype)
    full, _ = m.forward(params, dict(batch, tokens=toks))
    logits_full = m.logits(params, full)
    cache = m.init_cache(B, S + 3)
    lg, cache = m.prefill(params, batch, cache)
    errs = [float(jnp.abs(lg[:, 0] - logits_full[:, S - 1]).max())]
    for t in range(S, S + 2):
        lg, cache = m.decode_step(params, toks[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32), cache)
        errs.append(float(jnp.abs(lg[:, 0] - logits_full[:, t]).max()))
    scale = float(jnp.abs(logits_full).max()) + 1e-6
    assert max(errs) / scale < 0.05, errs


def test_registry_complete():
    assert len(ASSIGNED) == 10
    for arch in ASSIGNED:
        e = registry.get(arch)
        assert e.full.name and e.smoke.num_layers <= 8


def test_shape_cells_spec():
    """40 assigned cells: skips recorded exactly per the assignment."""
    from repro.configs.base import SHAPES, applicable_shapes, skip_reason
    total = live = 0
    for arch in ASSIGNED:
        cfg = registry.get(arch).full
        for shape in SHAPES.values():
            total += 1
            if skip_reason(cfg, shape) is None:
                live += 1
                assert shape in applicable_shapes(cfg)
    assert total == 40
    # encoder: -2 (no decode); 7 full-attention archs: -1 (long_500k)
    assert live == 40 - 2 - 7


@pytest.mark.parametrize("arch", ["resnet50", "resnet101", "resnet152"])
def test_resnet_param_counts_match_paper_table1(arch):
    """Paper Table 1: 25.56M / 44.55M / 60.19M."""
    cfg = registry.get(arch).full
    want = {"resnet50": 25.56e6, "resnet101": 44.55e6,
            "resnet152": 60.19e6}[arch]
    assert abs(cfg.param_count() - want) / want < 0.005

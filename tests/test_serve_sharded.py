"""Acceptance: a quantized + sharded tree serves under a 2-device mesh.

The main test process must keep seeing exactly 1 CPU device (see
conftest), so this runs in a subprocess with
``--xla_force_host_platform_device_count=2`` — the same trick
``launch/dryrun.py`` uses.  The child builds the smoke llama, applies
branched + SVD surgery (mixed tree), quantizes int8 *with the axes
rewrite*, resolves every leaf through ``make_param_shardings`` on a
``(1, 2)`` mesh (any unresolvable ``*_q``/``*_scale`` key raises —
"no key-resolution failures"), places the params, and serves
end-to-end.
"""
import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
from repro.core.surgery import decompose_model
from repro.models.api import get_model
from repro.parallel import sharding as shd
from repro.quant import quantize_tree
from repro.serve.engine import Request, ServeEngine

assert len(jax.devices()) == 2, jax.devices()
mesh = jax.make_mesh((1, 2), ("data", "model"))

cfg = registry.get("llama3.2-1b").smoke
# branches=2 with a small align so some layers branch and the rest take
# SVD pairs -> a mixed branched + SVD tree, per the acceptance criteria.
lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=32, branches=2,
                rank_align=8)
run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
m = get_model(cfg)
params, axes = m.init(jax.random.PRNGKey(0))
params, axes, report = decompose_model(params, axes, lrd)
kinds = {d.kind for d in report.decisions}
assert "branched" in kinds and "svd" in kinds, kinds

# Quantize AFTER the axes were built (the old failure mode), with the
# plan-level axes rewrite.
params, axes = quantize_tree(params, "int8", axes=axes)

# Every leaf must resolve -- k_q inherits k's axes, k_scale the out dim.
shardings = shd.make_param_shardings(mesh, params, axes, run.parallel)
params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, shardings)

eng = ServeEngine(run, params, slots=2, max_seq=64)
assert eng.plan_summary["quantized"] > 0, eng.plan_summary
assert eng.plan_summary["by_kind"].get("branched"), eng.plan_summary
reqs = [Request(uid=i, prompt=[i + 1, 2, 3], max_new_tokens=4)
        for i in range(3)]
for r in reqs:
    eng.add_request(r)
done = eng.run_until_done()
assert {r.uid for r in done} == {0, 1, 2}
assert all(r.done and len(r.output) == 4 for r in reqs)
print("OK", eng.plan_summary["by_kind"])
"""


def test_quantized_sharded_tree_serves_on_2dev_mesh():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "OK" in proc.stdout
